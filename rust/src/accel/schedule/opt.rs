//! The **TileProgram optimizer**: a pass manager over the flat instruction
//! stream of [`TileProgram`].
//!
//! The builder emits whatever the §3.9 loop nests produce — correct, but
//! strictly sequential and transfer-naive.  The paper's latency story is
//! *utilization*: independent processing modules run concurrently and data
//! stays in BRAM between modules.  This module recovers the software
//! analog of both as a pure compiler problem over the IR from PR 2:
//!
//! * [`DedupTransfers`] — redundant-transfer elimination.  An upload of a
//!   host scratch whose current contents already live in a device slot
//!   (an identical earlier upload, or a fetch of that very slot) is
//!   deleted and its slot aliased; duplicate panel extractions collapse.
//!   Bit-exact: the replaced slot holds bit-identical data.
//! * [`FuseAttention`] / [`FuseBiasLn`] — dispatch fusion.  A
//!   `qk_scores → softmax → sv` chain whose intermediates have no other
//!   reader collapses into one `attn_fused` dispatch; `bias_add_d →
//!   residual_ln` collapses into `bias_residual_ln`.  Applied only when
//!   the bound artifact set actually contains the fused artifact
//!   ([`ArtifactInventory`]), because fusion changes *which* programs run
//!   (numerics equivalent within the fused artifacts' tolerance, not
//!   bit-for-bit — hence [`OptLevel::O2`], not O1).
//! * [`ScheduleWaves`] — the wave scheduler: partitions the stream into
//!   **waves** of mutually independent instructions (ASAP list
//!   scheduling over the slot/host dependence graph) and reorders the
//!   stream so each wave is contiguous.  A wave is the PE-array
//!   parallelism analog: every member could execute concurrently on the
//!   fabric.  Replay remains sequential on the PJRT backend (bit-exact —
//!   it is a legal topological reorder), while the cycle backend may
//!   price a wave as `max` instead of `sum` over its members
//!   (`accel::sim::cycle::replay_program_waves`).
//! * [`CompactSlots`] — slot renaming from the same last-use analysis
//!   replay drops are computed from: device slot ids are renumbered with
//!   a linear-scan free list so `n_slots` shrinks from "one id per value"
//!   to the peak number of simultaneously live values.
//!
//! Legality rules (enforced by [`validate_waves`] after every pipeline
//! run): instruction B may share a wave with an earlier instruction A only
//! if B reads no slot/host A writes (RAW), writes none A reads (WAR) and
//! writes none A writes (WAW).  Panel assemblies into one host are
//! WAW-chained even though their column ranges are disjoint, keeping the
//! reorder bit-exact without reasoning about overlap.
//!
//! `TileEngine` runs the pipeline once per `(topology, flags, opt-level)`
//! and caches the optimized program; the request path replays it
//! unchanged.

use std::collections::{BTreeMap, BTreeSet, HashMap, HashSet};

use super::{Operand, RuntimeId, SlotId, Step, TileProgram};

/// Optimization level — part of the engine's program-cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum OptLevel {
    /// The builder's raw stream, untouched.
    O0,
    /// Bit-exact passes only: transfer dedup, wave scheduling, slot
    /// compaction.  Replay output is bit-identical to O0.
    O1,
    /// O1 plus dispatch fusion into the fused artifacts the bound
    /// artifact set provides (numerics within the fused kernels'
    /// tolerance; the serving default).
    #[default]
    O2,
}

/// The manifest interface of one artifact: operand shapes in dispatch
/// order plus output shapes.  Present only when the inventory was built
/// from a loaded manifest; name-only inventories carry no signatures and
/// the verifier skips signature-based checks for them.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSig {
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// The artifact names a fabric actually provides — fusion rewrites only
/// into artifacts that exist, so one optimized program never outruns the
/// artifact set it will replay against.
#[derive(Debug, Clone)]
pub struct ArtifactInventory {
    names: BTreeSet<String>,
    /// Manifest signatures keyed by artifact name, when known.
    sigs: BTreeMap<String, ArtifactSig>,
}

impl ArtifactInventory {
    pub fn from_names<I, S>(names: I) -> Self
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        ArtifactInventory {
            names: names.into_iter().map(Into::into).collect(),
            sigs: BTreeMap::new(),
        }
    }

    /// The inventory of a loaded artifact set — carries the manifest's
    /// per-artifact shape signatures, so the static verifier can check
    /// every dispatch interface against what the fabric really provides.
    pub fn from_manifest(m: &crate::runtime::Manifest) -> Self {
        let mut inv = Self::from_names(m.artifacts.keys().cloned());
        inv.sigs = m
            .artifacts
            .iter()
            .map(|(name, a)| {
                (
                    name.clone(),
                    ArtifactSig { inputs: a.inputs.clone(), outputs: a.outputs.clone() },
                )
            })
            .collect();
        inv
    }

    /// Every artifact the builder or the fusion passes can emit — for
    /// manifest-free consumers (the cycle backend prices all of them).
    pub fn assume_all() -> Self {
        Self::from_names([
            "mm_qkv",
            "mm_qkv_packed",
            "bias_add_qkv",
            "attn_packed",
            "mm_ffn1",
            "mm_ffn2",
            "mm_ffn3",
            "qk_scores",
            "softmax",
            "sv",
            "attn_fused",
            "bias_add_dk",
            "bias_add_d",
            "bias_relu_h",
            "residual_ln",
            "quantize",
            "bias_residual_ln",
            // decode-step row artifacts (accel::decode)
            "dec_qkv_row",
            "qk_row",
            "softmax_row",
            "sv_row",
            "kv_append",
            "dec_proj_row",
            "dec_ffn1_row",
            "dec_ffn2_row",
            "residual_ln_row",
        ])
    }

    pub fn has(&self, name: &str) -> bool {
        self.names.contains(name)
    }

    /// The manifest signature of `name`, when this inventory carries one.
    pub fn signature(&self, name: &str) -> Option<&ArtifactSig> {
        self.sigs.get(name)
    }
}

/// Context handed to every pass.
pub struct PassCx<'a> {
    pub inventory: &'a ArtifactInventory,
}

/// One rewrite over the program.  Passes mutate in place and report how
/// many rewrites they applied; `TileProgram::finalize` is re-run by the
/// pipeline once at the end, so passes need not maintain the drop lists.
pub trait Pass {
    fn name(&self) -> &'static str;
    fn run(&self, prog: &mut TileProgram, cx: &PassCx<'_>) -> usize;
}

/// What a pipeline run did, pass by pass.
#[derive(Debug, Clone, Default)]
pub struct OptReport {
    /// `(pass name, rewrites applied)` in execution order.
    pub applied: Vec<(&'static str, usize)>,
}

impl OptReport {
    pub fn total_rewrites(&self) -> usize {
        self.applied.iter().map(|(_, n)| n).sum()
    }
}

/// An ordered pass list.
pub struct Pipeline {
    passes: Vec<Box<dyn Pass>>,
}

impl Pipeline {
    /// The canonical pipeline for `level`:
    /// O0 → (empty); O1 → dedup, waves, compact;
    /// O2 → dedup, fuse-attention, fuse-bias-ln, waves, compact.
    /// Fusion runs before wave scheduling so fused dispatches (fewer,
    /// fatter) are what the waves partition.
    pub fn for_level(level: OptLevel) -> Self {
        let mut passes: Vec<Box<dyn Pass>> = Vec::new();
        if level == OptLevel::O0 {
            return Pipeline { passes };
        }
        passes.push(Box::new(DedupTransfers));
        if level == OptLevel::O2 {
            passes.push(Box::new(FuseAttention));
            passes.push(Box::new(FuseBiasLn));
        }
        passes.push(Box::new(ScheduleWaves));
        passes.push(Box::new(CompactSlots));
        Pipeline { passes }
    }

    /// Run every pass, re-finalize the program, and check wave legality.
    /// A validation failure means an optimizer bug; it surfaces as an
    /// error (failing the one cache-miss request) rather than a panic on
    /// the serving path.
    pub fn run(
        &self,
        prog: &mut TileProgram,
        inventory: &ArtifactInventory,
    ) -> anyhow::Result<OptReport> {
        let cx = PassCx { inventory };
        let mut report = OptReport::default();
        for pass in &self.passes {
            let n = pass.run(prog, &cx);
            report.applied.push((pass.name(), n));
            // Debug builds run the kind-agnostic static verifier after
            // every pass: a pass that corrupts dataflow, shapes, or wave
            // legality is caught at the pass boundary that introduced the
            // bug, not at the end of the pipeline.
            #[cfg(debug_assertions)]
            {
                let rep = super::verify::verify_structure(prog, inventory);
                if !rep.is_clean() {
                    let msgs: Vec<String> = rep.errors().map(ToString::to_string).collect();
                    anyhow::bail!(
                        "pass '{}' left the program malformed: {}",
                        pass.name(),
                        msgs.join("; ")
                    );
                }
            }
        }
        prog.finalize();
        validate_waves(prog).map_err(|e| {
            anyhow::Error::new(e).context("optimizer produced an illegal wave partition")
        })?;
        Ok(report)
    }
}

/// Optimize `prog` at `level` against `inventory` — the one-call entry
/// the engine and the cycle tools use.
pub fn optimize(
    prog: &mut TileProgram,
    level: OptLevel,
    inventory: &ArtifactInventory,
) -> anyhow::Result<OptReport> {
    Pipeline::for_level(level).run(prog, inventory)
}

// ---- dependence bookkeeping ---------------------------------------------

/// Read/write sets of one step over the two operand namespaces.  Panel
/// assembly is modeled as a plain write of its destination host; the WAW
/// edge to the previous writer keeps read-modify-write ordering intact.
struct Access {
    slot_reads: Vec<SlotId>,
    slot_writes: Vec<SlotId>,
    host_reads: Vec<super::HostId>,
    host_writes: Vec<super::HostId>,
}

fn access(step: &Step) -> Access {
    let mut a = Access {
        slot_reads: Vec::new(),
        slot_writes: Vec::new(),
        host_reads: Vec::new(),
        host_writes: Vec::new(),
    };
    match step {
        Step::Upload { host, dst } => {
            a.host_reads.push(*host);
            a.slot_writes.push(*dst);
        }
        Step::Dispatch { args, dst, .. } => {
            for arg in args {
                if let Operand::Slot(s) = arg {
                    a.slot_reads.push(*s);
                }
            }
            a.slot_writes.push(*dst);
        }
        Step::Fetch { src, host } => {
            a.slot_reads.push(*src);
            a.host_writes.push(*host);
        }
        Step::ExtractPanel { src, dst, .. } => {
            a.host_reads.push(*src);
            a.host_writes.push(*dst);
        }
        Step::AssemblePanel { src, dst, .. } => {
            a.host_reads.push(*src);
            a.host_writes.push(*dst);
        }
        Step::CalibrateScale { src, dst } => {
            a.host_reads.push(*src);
            a.slot_writes.push(*dst);
        }
        // A send is a fetch with link pricing; a recv only observes the
        // caller-written input host (the activation arrived off-program).
        Step::SendActivation { src, host, .. } => {
            a.slot_reads.push(*src);
            a.host_writes.push(*host);
        }
        Step::RecvActivation { host, .. } => {
            a.host_reads.push(*host);
        }
    }
    a
}

/// The step indices `i` depends on in the current stream order —
/// RAW/WAR/WAW over *both* operand namespaces.  On the SSA stream the
/// wave scheduler sees (every slot written exactly once, before all its
/// reads), the slot WAR/WAW edges are vacuous; they exist so that
/// [`validate_waves`], which re-runs after `CompactSlots` has recycled
/// slot ids, catches any reuse that would make wave members race.
pub(super) fn dependence_lists(prog: &TileProgram) -> Vec<Vec<usize>> {
    let n_hosts = prog.host_shapes.len();
    let mut slot_writer: HashMap<SlotId, usize> = HashMap::new();
    let mut slot_readers: HashMap<SlotId, Vec<usize>> = HashMap::new();
    let mut host_last_write: Vec<Option<usize>> = vec![None; n_hosts];
    let mut host_readers: Vec<Vec<usize>> = vec![Vec::new(); n_hosts];
    let mut deps = Vec::with_capacity(prog.steps.len());
    for (i, step) in prog.steps.iter().enumerate() {
        let a = access(step);
        let mut d: Vec<usize> = Vec::new();
        for s in &a.slot_reads {
            if let Some(&w) = slot_writer.get(s) {
                d.push(w);
            }
        }
        for s in &a.slot_writes {
            // WAR/WAW on a recycled slot id: wait for every reference to
            // the id's previous value.
            if let Some(rs) = slot_readers.get(s) {
                d.extend(rs.iter().copied());
            }
            if let Some(&w) = slot_writer.get(s) {
                d.push(w);
            }
        }
        for h in &a.host_reads {
            if let Some(w) = host_last_write[*h] {
                d.push(w);
            }
        }
        for h in &a.host_writes {
            // WAR: wait for every read of the previous version; WAW: and
            // for the previous writer.
            d.extend(host_readers[*h].iter().copied());
            if let Some(w) = host_last_write[*h] {
                d.push(w);
            }
        }
        // State updates after dependence collection (a step never depends
        // on itself; reads see the pre-step state).
        for h in &a.host_reads {
            host_readers[*h].push(i);
        }
        for s in &a.slot_reads {
            slot_readers.entry(*s).or_default().push(i);
        }
        for s in &a.slot_writes {
            slot_writer.insert(*s, i);
            slot_readers.entry(*s).or_default().clear();
        }
        for h in &a.host_writes {
            host_last_write[*h] = Some(i);
            host_readers[*h].clear();
        }
        d.sort_unstable();
        d.dedup();
        deps.push(d);
    }
    deps
}

/// Check the program's wave partition: every dependence must cross a wave
/// boundary backwards (members of one wave are mutually independent).  A
/// program without waves is trivially valid (sequential semantics).
///
/// A thin typed wrapper over [`super::verify::wave_diagnostics`] — the
/// full static verifier reports the same analysis as structured,
/// step-anchored diagnostics.
pub fn validate_waves(prog: &TileProgram) -> Result<(), super::verify::VerifyError> {
    let diags = super::verify::wave_diagnostics(prog);
    if diags.iter().any(|d| d.severity == super::verify::Severity::Error) {
        return Err(super::verify::VerifyError::new(diags));
    }
    Ok(())
}

// ---- pass: redundant-transfer elimination -------------------------------

/// Deletes uploads whose payload is already device-resident and duplicate
/// panel extractions.  Host contents are tracked by a per-host version
/// counter (bumped on every write); an `Upload` of `(host, version)` that
/// matches an earlier upload — or a `Fetch` that produced exactly that
/// version — aliases its destination slot to the resident one.
pub struct DedupTransfers;

impl DedupTransfers {
    fn rewrite(
        step: &mut Step,
        slot_alias: &HashMap<SlotId, SlotId>,
        host_alias: &HashMap<super::HostId, super::HostId>,
    ) {
        let slot = |s: &mut SlotId| {
            if let Some(a) = slot_alias.get(s) {
                *s = *a;
            }
        };
        let host = |h: &mut super::HostId| {
            if let Some(a) = host_alias.get(h) {
                *h = *a;
            }
        };
        match step {
            Step::Upload { host: h, .. } => host(h),
            Step::Dispatch { args, .. } => {
                for arg in args {
                    if let Operand::Slot(s) = arg {
                        slot(s);
                    }
                }
            }
            Step::Fetch { src, .. } => slot(src),
            Step::ExtractPanel { src, .. } => host(src),
            Step::AssemblePanel { src, .. } => host(src),
            Step::CalibrateScale { src, .. } => host(src),
            Step::SendActivation { src, .. } => slot(src),
            Step::RecvActivation { .. } => {}
        }
    }
}

impl Pass for DedupTransfers {
    fn name(&self) -> &'static str {
        "dedup-transfers"
    }

    fn run(&self, prog: &mut TileProgram, _cx: &PassCx<'_>) -> usize {
        let n_hosts = prog.host_shapes.len();
        // Hosts written exactly once can be aliased away wholesale (their
        // defining step is the deleted duplicate); anything rewritten
        // later must keep its own identity.
        let mut host_writes = vec![0usize; n_hosts];
        host_writes[prog.input_host] += 1; // the caller's pre-replay write
        for step in &prog.steps {
            for h in access(step).host_writes {
                host_writes[h] += 1;
            }
        }

        let mut host_ver = vec![0u32; n_hosts];
        // (host, version) → device slot holding exactly that content.
        let mut resident: HashMap<(super::HostId, u32), SlotId> = HashMap::new();
        // (src host, version, c0, width) → host holding that panel.
        let mut extracted: HashMap<(super::HostId, u32, usize, usize), super::HostId> =
            HashMap::new();
        let mut slot_alias: HashMap<SlotId, SlotId> = HashMap::new();
        let mut host_alias: HashMap<super::HostId, super::HostId> = HashMap::new();
        let mut removed = 0usize;

        let steps = std::mem::take(&mut prog.steps);
        let mut out = Vec::with_capacity(steps.len());
        // Exported slots keep their identity (they are all dispatch
        // outputs, never upload targets — the alias remap below is
        // defensive for future step kinds).
        for mut step in steps {
            Self::rewrite(&mut step, &slot_alias, &host_alias);
            match &step {
                Step::Upload { host, dst } => {
                    if let Some(&s) = resident.get(&(*host, host_ver[*host])) {
                        slot_alias.insert(*dst, s);
                        removed += 1;
                        continue;
                    }
                    resident.insert((*host, host_ver[*host]), *dst);
                }
                Step::Fetch { src, host } => {
                    host_ver[*host] += 1;
                    // The fetched host now mirrors the device slot: a later
                    // upload of this exact version is a round trip.
                    resident.insert((*host, host_ver[*host]), *src);
                }
                Step::ExtractPanel { src, c0, width, dst } => {
                    let key = (*src, host_ver[*src], *c0, *width);
                    match extracted.get(&key) {
                        Some(&h) if host_writes[*dst] == 1 && host_writes[h] == 1 => {
                            host_alias.insert(*dst, h);
                            removed += 1;
                            continue;
                        }
                        _ => {
                            extracted.insert(key, *dst);
                            host_ver[*dst] += 1;
                        }
                    }
                }
                Step::AssemblePanel { dst, .. } => {
                    host_ver[*dst] += 1;
                }
                // Same residency bookkeeping as Fetch: after a send the
                // host mirrors the device slot.
                Step::SendActivation { src, host, .. } => {
                    host_ver[*host] += 1;
                    resident.insert((*host, host_ver[*host]), *src);
                }
                Step::Dispatch { .. }
                | Step::CalibrateScale { .. }
                | Step::RecvActivation { .. } => {}
            }
            out.push(step);
        }
        prog.steps = out;
        for s in prog.export_slots.iter_mut() {
            if let Some(a) = slot_alias.get(s) {
                *s = *a;
            }
        }
        removed
    }
}

// ---- pass: dispatch fusion ----------------------------------------------

/// `(writer step, read count)` per slot of the current stream — the
/// single-use analysis both fusion passes gate on.
fn slot_dataflow(steps: &[Step]) -> (HashMap<SlotId, usize>, HashMap<SlotId, usize>) {
    let mut writer = HashMap::new();
    let mut uses: HashMap<SlotId, usize> = HashMap::new();
    for (i, step) in steps.iter().enumerate() {
        let a = access(step);
        for s in a.slot_reads {
            *uses.entry(s).or_default() += 1;
        }
        for s in a.slot_writes {
            writer.insert(s, i);
        }
    }
    (writer, uses)
}

/// Shared fusion scaffolding: `matcher` inspects anchor step `i` against
/// the stream's single-use dataflow and returns the earlier steps to
/// delete plus the fused replacement for `i`.  Applies every match, then
/// rebuilds the stream without the deleted steps.
fn rewrite_fused<F>(prog: &mut TileProgram, matcher: F) -> usize
where
    F: Fn(
        &[Step],
        usize,
        &HashMap<SlotId, usize>,
        &HashMap<SlotId, usize>,
    ) -> Option<(Vec<usize>, Step)>,
{
    let (writer, mut uses) = slot_dataflow(&prog.steps);
    // An exported slot has an implicit extra reader (the caller), so a
    // chain producing one never counts as single-use and is never fused
    // away.
    for s in &prog.export_slots {
        *uses.entry(*s).or_default() += 1;
    }
    let mut remove = vec![false; prog.steps.len()];
    let mut replace: Vec<(usize, Step)> = Vec::new();
    for i in 0..prog.steps.len() {
        if let Some((kill, step)) = matcher(prog.steps.as_slice(), i, &writer, &uses) {
            for j in kill {
                remove[j] = true;
            }
            replace.push((i, step));
        }
    }
    let fused = replace.len();
    for (i, step) in replace {
        prog.steps[i] = step;
    }
    let steps = std::mem::take(&mut prog.steps);
    prog.steps =
        steps.into_iter().enumerate().filter(|(i, _)| !remove[*i]).map(|(_, s)| s).collect();
    fused
}

/// Collapses `qk_scores → softmax → sv` chains whose score/probability
/// slots have exactly one reader into a single `attn_fused` dispatch —
/// the per-head split-attention chain becomes the fused artifact.
pub struct FuseAttention;

impl Pass for FuseAttention {
    fn name(&self) -> &'static str {
        "fuse-attention"
    }

    fn run(&self, prog: &mut TileProgram, cx: &PassCx<'_>) -> usize {
        if !cx.inventory.has("attn_fused") {
            return 0;
        }
        rewrite_fused(prog, |steps, i, writer, uses| {
            let Step::Dispatch { artifact: "sv", args: sv_args, dst, out_shape, pred } = &steps[i]
            else {
                return None;
            };
            let [Operand::Slot(p), v_arg] = sv_args.as_slice() else { return None };
            if uses.get(p) != Some(&1) {
                return None;
            }
            let j = *writer.get(p)?;
            let Step::Dispatch { artifact: "softmax", args: sm_args, pred: sm_pred, .. } =
                &steps[j]
            else {
                return None;
            };
            let [Operand::Slot(s)] = sm_args.as_slice() else { return None };
            if uses.get(s) != Some(&1) {
                return None;
            }
            let k = *writer.get(s)?;
            let Step::Dispatch { artifact: "qk_scores", args: qk_args, pred: qk_pred, .. } =
                &steps[k]
            else {
                return None;
            };
            // Skippable tiers fuse tier-by-tier: the whole triple must
            // share one predicate (the fused step inherits it), so a
            // fired tier still runs its complete chain and a skipped one
            // skips it whole.
            if sm_pred != pred || qk_pred != pred {
                return None;
            }
            let [q_arg, k_arg, mask_arg, scale_arg] = qk_args.as_slice() else { return None };
            // Causal gate: decoder masked self-attention keeps the split
            // chain so the prefill path shares numerics (and artifacts)
            // with the row-shaped decode-step chain — the fused rectangle
            // kernel is left to the encoder/cross chains.  Tiered causal
            // fences are causal chains too.
            if matches!(
                mask_arg,
                Operand::Runtime(RuntimeId::CausalMask | RuntimeId::TierCausalMask(_))
            ) {
                return None;
            }
            Some((
                vec![j, k],
                Step::Dispatch {
                    artifact: "attn_fused",
                    args: vec![
                        q_arg.clone(),
                        k_arg.clone(),
                        v_arg.clone(),
                        mask_arg.clone(),
                        scale_arg.clone(),
                    ],
                    dst: *dst,
                    out_shape: out_shape.clone(),
                    pred: *pred,
                },
            ))
        })
    }
}

/// Collapses `bias_add_d → residual_ln` (the FFN-chain bias + LayerNorm
/// pair, twice per layer) into one `bias_residual_ln` dispatch when the
/// artifact set provides it (`python/compile/aot.py` emits it).
pub struct FuseBiasLn;

impl Pass for FuseBiasLn {
    fn name(&self) -> &'static str {
        "fuse-bias-ln"
    }

    fn run(&self, prog: &mut TileProgram, cx: &PassCx<'_>) -> usize {
        if !cx.inventory.has("bias_residual_ln") {
            return 0;
        }
        rewrite_fused(prog, |steps, i, writer, uses| {
            // The bias/LN pair is never predicated (only attention chains
            // tier); require both unpredicated so the fusion stays exact.
            let Step::Dispatch {
                artifact: "residual_ln",
                args: ln_args,
                dst,
                out_shape,
                pred: None,
            } = &steps[i]
            else {
                return None;
            };
            let Some(Operand::Slot(b)) = ln_args.first() else { return None };
            if uses.get(b) != Some(&1) {
                return None;
            }
            let j = *writer.get(b)?;
            let Step::Dispatch { artifact: "bias_add_d", args: bias_args, pred: None, .. } =
                &steps[j]
            else {
                return None;
            };
            let [x_arg, bias_arg] = bias_args.as_slice() else { return None };
            // bias_residual_ln(x, bias, res, gamma, beta, dmask, count)
            let mut args = vec![x_arg.clone(), bias_arg.clone()];
            args.extend(ln_args[1..].iter().cloned());
            Some((
                vec![j],
                Step::Dispatch {
                    artifact: "bias_residual_ln",
                    args,
                    dst: *dst,
                    out_shape: out_shape.clone(),
                    pred: None,
                },
            ))
        })
    }
}

// ---- pass: wave scheduling ----------------------------------------------

/// ASAP list scheduling: each step's wave is one past the latest wave any
/// of its dependences landed in; the stream is stably reordered so every
/// wave is contiguous.  Members of one wave are mutually independent by
/// construction — the PE-array parallelism the sequential stream hid.
pub struct ScheduleWaves;

impl Pass for ScheduleWaves {
    fn name(&self) -> &'static str {
        "schedule-waves"
    }

    fn run(&self, prog: &mut TileProgram, _cx: &PassCx<'_>) -> usize {
        let deps = dependence_lists(prog);
        let n = prog.steps.len();
        let mut level = vec![0usize; n];
        for i in 0..n {
            level[i] = deps[i].iter().map(|&j| level[j] + 1).max().unwrap_or(0);
        }
        let n_waves = level.iter().map(|l| l + 1).max().unwrap_or(0);
        // Stable bucket order: original index order within each level.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| (level[i], i));
        let steps = std::mem::take(&mut prog.steps);
        let mut indexed: Vec<Option<Step>> = steps.into_iter().map(Some).collect();
        prog.steps = order.iter().map(|&i| indexed[i].take().unwrap()).collect();
        let mut waves = Vec::with_capacity(n_waves);
        let mut count = 0usize;
        let mut cur = 0usize;
        for &i in &order {
            if level[i] != cur {
                waves.push(count);
                cur = level[i];
            }
            count += 1;
        }
        if n > 0 {
            waves.push(count);
        }
        prog.waves = waves;
        // Report steps actually displaced, not the wave count.
        order.iter().enumerate().filter(|(new, &old)| *new != old).count()
    }
}

// ---- pass: slot compaction ----------------------------------------------

/// Linear-scan slot renaming: device slot ids are reassigned from a free
/// list as their last use passes, shrinking `n_slots` (and replay's slot
/// table) from "one id per value" to the peak live count — the on-chip
/// buffer footprint the last-use analysis already implied.
///
/// **Wave discipline:** on a wave-scheduled program an id freed inside
/// wave W becomes reusable only from wave W+1 — reusing it within W
/// would put a reader of the old value and the writer of the new one in
/// the same (conceptually concurrent) wave, breaking the independence
/// contract [`validate_waves`] enforces.  Unscheduled programs recycle
/// immediately (sequential semantics).
pub struct CompactSlots;

impl Pass for CompactSlots {
    fn name(&self) -> &'static str {
        "compact-slots"
    }

    fn run(&self, prog: &mut TileProgram, _cx: &PassCx<'_>) -> usize {
        let n = prog.steps.len();
        // Last reference (read or write) per slot, in current order.
        let mut last: HashMap<SlotId, usize> = HashMap::new();
        for (i, step) in prog.steps.iter().enumerate() {
            let a = access(step);
            for s in a.slot_reads.iter().chain(a.slot_writes.iter()) {
                last.insert(*s, i);
            }
        }
        // Exported slots live past the program's end (replay hands them
        // to the caller): never retire their ids.
        let exported: HashSet<SlotId> = prog.export_slots.iter().copied().collect();
        let mut map: HashMap<SlotId, SlotId> = HashMap::new();
        let mut free: Vec<SlotId> = Vec::new();
        // Ids retired during the current wave, released at its boundary.
        let mut pending: Vec<SlotId> = Vec::new();
        let mut wave = 0usize;
        let mut next = 0usize;
        for i in 0..n {
            let a = access(&prog.steps[i]);
            // Rewrite reads, then retire slots whose last use is this
            // step (into `pending` until the wave ends), then name the
            // writes.
            let rewrite_read = |s: &mut SlotId, map: &HashMap<SlotId, SlotId>| {
                *s = *map.get(s).expect("read of a slot that was never written");
            };
            match &mut prog.steps[i] {
                Step::Dispatch { args, .. } => {
                    for arg in args {
                        if let Operand::Slot(s) = arg {
                            rewrite_read(s, &map);
                        }
                    }
                }
                Step::Fetch { src, .. } | Step::SendActivation { src, .. } => {
                    rewrite_read(src, &map)
                }
                _ => {}
            }
            let mut retired = a.slot_reads.clone();
            retired.sort_unstable();
            retired.dedup();
            for s in &retired {
                if last.get(s) == Some(&i) && !exported.contains(s) {
                    pending.push(map[s]);
                }
            }
            for s in &a.slot_writes {
                // A write to an already-named slot is a disjoint-pred twin
                // def (skippable tiers converging on one output): it must
                // keep the shared id, not shadow it — replay fires exactly
                // one twin and downstream readers resolve the shared id.
                // (A stale mapping is impossible: a slot is retired only
                // after its final reference, so a re-written slot is live.)
                let new = match map.get(s) {
                    Some(&id) => id,
                    None => free.pop().unwrap_or_else(|| {
                        next += 1;
                        next - 1
                    }),
                };
                map.insert(*s, new);
                match &mut prog.steps[i] {
                    Step::Upload { dst, .. }
                    | Step::Dispatch { dst, .. }
                    | Step::CalibrateScale { dst, .. } => *dst = new,
                    _ => unreachable!("slot writes only come from upload/dispatch/calibrate"),
                }
                // A value written and never read dies immediately.
                if last.get(s) == Some(&i) && !exported.contains(s) {
                    pending.push(new);
                }
            }
            // Release retired ids: at the wave boundary for scheduled
            // programs, immediately for sequential ones.
            let at_boundary = match prog.waves.get(wave) {
                Some(&end) => {
                    if i + 1 == end {
                        wave += 1;
                        true
                    } else {
                        false
                    }
                }
                None => true,
            };
            if at_boundary {
                free.append(&mut pending);
            }
        }
        // Exported slots were renamed like everything else — update the
        // export table to the compacted ids.
        for s in prog.export_slots.iter_mut() {
            *s = *map.get(s).expect("export slot was never written");
        }
        let saved = prog.n_slots.saturating_sub(next);
        prog.n_slots = next;
        saved
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FabricConstants, ScheduleBuilder};
    use super::*;
    use crate::model::presets;

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    fn raw(seq: usize, layers: usize) -> TileProgram {
        ScheduleBuilder::new(fc(), presets::small_encoder(seq, layers)).unwrap().build()
    }

    #[test]
    fn o0_pipeline_is_identity() {
        let mut p = raw(32, 1);
        let before = p.steps.clone();
        let rep = optimize(&mut p, OptLevel::O0, &ArtifactInventory::assume_all()).unwrap();
        assert_eq!(rep.total_rewrites(), 0);
        assert_eq!(p.steps, before);
        assert_eq!(p.wave_count(), 0, "O0 leaves the program unscheduled");
    }

    #[test]
    fn o1_preserves_the_dispatch_multiset_and_partitions_waves() {
        let mut p = raw(32, 2);
        let mut names_before: Vec<&str> = p.dispatch_sequence();
        names_before.sort_unstable();
        let (d, u, f) = (p.dispatch_count(), p.upload_count(), p.fetch_count());
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        let mut names_after = p.dispatch_sequence();
        names_after.sort_unstable();
        assert_eq!(names_before, names_after, "O1 may only reorder/dedup, never change ops");
        assert_eq!(p.dispatch_count(), d);
        assert!(p.upload_count() <= u);
        assert_eq!(p.fetch_count(), f);
        assert!(p.wave_count() > 1, "the stream must split into waves");
        assert!(p.wave_count() < p.steps.len(), "waves must actually group steps");
        validate_waves(&p).unwrap();
    }

    #[test]
    fn waves_expose_cross_head_parallelism() {
        // 4 heads: the four per-head mm_qkv chains are independent, so at
        // least one wave must hold 4 concurrent dispatches.
        let mut p = raw(32, 1);
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        let widest = p
            .wave_ranges()
            .into_iter()
            .map(|r| {
                p.steps[r]
                    .iter()
                    .filter(|s| matches!(s, Step::Dispatch { .. }))
                    .count()
            })
            .max()
            .unwrap();
        assert!(widest >= 4, "widest wave has {widest} dispatches, want >= heads");
    }

    #[test]
    fn o2_fuses_attention_and_bias_ln() {
        let mut p = raw(32, 2);
        let d0 = p.dispatch_count();
        let u0 = p.upload_count();
        let heads = p.cfg.heads * p.cfg.enc_layers;
        optimize(&mut p, OptLevel::O2, &ArtifactInventory::assume_all()).unwrap();
        let seq = p.dispatch_sequence();
        assert!(!seq.contains(&"qk_scores"));
        assert!(!seq.contains(&"softmax"));
        assert!(!seq.contains(&"sv"));
        assert!(!seq.contains(&"bias_add_d"));
        assert!(seq.contains(&"attn_fused"));
        assert!(seq.contains(&"bias_residual_ln"));
        // 3→1 per head per layer, 2→1 twice per layer
        assert_eq!(p.dispatch_count(), d0 - 2 * heads - 2 * p.cfg.enc_layers);
        assert!(p.upload_count() <= u0);
        assert!(
            p.dispatch_count() + p.upload_count() < d0 + u0,
            "the optimized replay must be strictly cheaper"
        );
        validate_waves(&p).unwrap();
    }

    #[test]
    fn fusion_respects_the_artifact_inventory() {
        let mut p = raw(32, 1);
        let d0 = p.dispatch_count();
        // An inventory without the fused artifacts: fusion must not fire.
        let inv = ArtifactInventory::from_names(["qk_scores", "softmax", "sv"]);
        optimize(&mut p, OptLevel::O2, &inv).unwrap();
        assert_eq!(p.dispatch_count(), d0);
        assert!(p.dispatch_sequence().contains(&"qk_scores"));
        assert!(!p.dispatch_sequence().contains(&"attn_fused"));
    }

    #[test]
    fn compaction_shrinks_the_slot_table() {
        let mut p = raw(32, 2);
        let n0 = p.n_slots;
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        assert!(p.n_slots < n0, "slot renaming must reuse freed ids ({} vs {n0})", p.n_slots);
        // The compacted table must still be big enough for every reference.
        let max_ref = p
            .steps
            .iter()
            .flat_map(|s| {
                let a = super::access(s);
                a.slot_reads.into_iter().chain(a.slot_writes)
            })
            .max()
            .unwrap();
        assert!(max_ref < p.n_slots);
    }

    #[test]
    fn quantized_and_packed_streams_optimize_cleanly() {
        for (packed, quantized) in [(true, false), (false, true), (true, true)] {
            let mut p = ScheduleBuilder::new(fc(), presets::small_encoder(32, 1))
                .unwrap()
                .qkv_packed(packed)
                .quantized(quantized)
                .build();
            let mut before: Vec<&str> = p.dispatch_sequence();
            before.sort_unstable();
            optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
            let mut after = p.dispatch_sequence();
            after.sort_unstable();
            assert_eq!(before, after, "packed={packed} quantized={quantized}");
            validate_waves(&p).unwrap();
        }
    }

    #[test]
    fn validator_rejects_a_forged_partition() {
        let mut p = raw(16, 1);
        optimize(&mut p, OptLevel::O1, &ArtifactInventory::assume_all()).unwrap();
        // Forge: collapse everything into one wave — dependences now share
        // a wave, which the validator must reject.
        p.waves = vec![p.steps.len()];
        assert!(validate_waves(&p).is_err());
    }
}
