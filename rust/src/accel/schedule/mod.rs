//! The **TileProgram IR** — the tile schedule of Algorithms 1–17 as data.
//!
//! The paper's host software walks the §3.9 tile schedules as imperative
//! loops welded to one executor.  This module extracts that schedule into a
//! flat instruction stream built **once per topology** by the
//! [`builder::ScheduleBuilder`] and replayed per request:
//!
//! ```text
//! (TnnConfig, FabricConstants, AttentionMode, qkv_packed, quantized)
//!         │ ScheduleBuilder::build            (once per topology)
//!         ▼
//!     TileProgram  ── replay ──▶ FabricBackend (PJRT Executor: numerics)
//!                  ── replay ──▶ CycleBackend  (accel::sim: predicted cycles)
//! ```
//!
//! Both backends walk the *same* program, so the Table 2
//! analytical-vs-experimental comparison and the serving request path
//! consume one source of truth — the overlay-processor structure of NPE
//! (software-built instruction stream, fixed hardware) and AccelTran's
//! simulate-what-you-execute discipline.
//!
//! Before a program is cached, the [`opt`] pass pipeline rewrites it
//! (transfer dedup, dispatch fusion into the manifest's fused artifacts,
//! **wave scheduling** — contiguous groups of mutually independent
//! instructions, the PE-array parallelism analog — and slot compaction);
//! see DESIGN.md §Program optimization.
//!
//! The instruction set mirrors what the fabric substrate can do:
//!
//! * [`Step::Upload`] / [`Step::Fetch`] — host ↔ device (AXI DMA analog);
//! * [`Step::Dispatch`] — run one fixed-shape AOT artifact over operand
//!   slots (a processing-module invocation);
//! * [`Step::ExtractPanel`] / [`Step::AssemblePanel`] — host-side column
//!   panel (re)assembly between module chains (the BRAM bank-to-bank moves
//!   the paper gets for free inside the fabric);
//! * [`Step::CalibrateScale`] — data-dependent int8 scale calibration for
//!   the quantized path (the one step whose *value* cannot be baked into
//!   the program).
//!
//! Operands are virtual: transient device [`Operand::Slot`]s, per-topology
//! [`Operand::Runtime`] tensors (masks — padding and causal — dmask,
//! count, zero accumulators — uploaded once and reused across requests),
//! [`Operand::Weight`] references resolved against whichever weight stack
//! is bound at replay time (so one program serves every model with the
//! same topology), and [`Operand::Extern`] caller-held device buffers —
//! the KV-cache panels of the decoder path.
//!
//! Three program flavors exist per topology: the encoder stack
//! ([`builder::ScheduleBuilder::build`]), the decoder **prefill**
//! ([`builder::ScheduleBuilder::build_prefill`] — whole prompt, exports
//! the K/V panels that seed `accel::decode::KvCache`), and the
//! single-token **decode-step**
//! ([`builder::ScheduleBuilder::build_step`] — row-shaped artifacts
//! against the cached K/V, appending on-device); see DESIGN.md §Decoder
//! execution & KV cache.

pub mod builder;
pub mod opt;
pub mod verify;

pub use builder::ScheduleBuilder;
pub use opt::{optimize, ArtifactInventory, ArtifactSig, OptLevel, OptReport};
pub use verify::{Diagnostic, Rule, Severity, VerifyError, VerifyReport};

use anyhow::{anyhow, bail};

use crate::model::TnnConfig;
use crate::runtime::backend::FabricBackend;
use crate::runtime::{Manifest, Tensor};

/// Attention execution mode: `Split` mirrors the paper's module chain
/// (QK_PM → softmax → SV_PM); `Fused` is the single-pass perf path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AttentionMode {
    Split,
    Fused,
}

/// Which instruction stream a cache entry holds for a topology: the
/// encoder stack, the decoder prefill (whole prompt, exports the KV
/// cache), or the KV-cached decode step (one token row).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ProgramKind {
    Encoder,
    Prefill,
    DecodeStep,
}

/// The synthesis-time shape constants of the fabric — everything the
/// builder needs to lower a topology, decoupled from the artifact manifest
/// so programs (and cycle estimates) can be built without an artifact set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricConstants {
    /// Maximum sequence length (input BRAM rows).
    pub sl_max: usize,
    /// Fixed per-head width.
    pub dk: usize,
    /// MHA tile width (§3.9, Fig 4a).
    pub ts_mha: usize,
    /// FFN tile width (§3.9, Fig 4b).
    pub ts_ffn: usize,
    /// FFN2/FFN3 hidden-side panel width.
    pub ffn_col: usize,
    /// Maximum embedding width the buffers were sized for.
    pub dmodel_max: usize,
    /// Maximum hidden width.
    pub hidden_max: usize,
}

impl FabricConstants {
    /// The constants of a loaded artifact set.
    pub fn from_manifest(m: &Manifest) -> Self {
        FabricConstants {
            sl_max: m.sl_max,
            dk: m.dk,
            ts_mha: m.ts_mha,
            ts_ffn: m.ts_ffn,
            ffn_col: m.ffn_col,
            dmodel_max: m.dmodel_max,
            hidden_max: m.hidden_max,
        }
    }

    /// The default artifact set's constants (python/compile/configs.py) —
    /// lets schedule/cycle tests run without the AOT lowering step.
    pub fn artifact_default() -> Self {
        FabricConstants {
            sl_max: 128,
            dk: 64,
            ts_mha: 64,
            ts_ffn: 128,
            ffn_col: 512,
            dmodel_max: 768,
            hidden_max: 3072,
        }
    }

    /// The tile geometry these constants describe.
    pub fn tile_config(&self) -> crate::accel::tiling::TileConfig {
        crate::accel::tiling::TileConfig::new(self.ts_mha, self.ts_ffn)
    }

    /// Fabric divisibility/maxima constraints for executing `cfg` (the
    /// FPGA's equivalents are the tile sizes baked at synthesis).
    pub fn check(&self, cfg: &TnnConfig) -> std::result::Result<(), String> {
        cfg.validate_for_execution()?;
        if cfg.seq_len > self.sl_max {
            return Err(format!("seq_len {} > fabric SL_MAX {}", cfg.seq_len, self.sl_max));
        }
        if cfg.dk() != self.dk {
            return Err(format!(
                "d_model/heads = {} but the fabric's head width is {}",
                cfg.dk(),
                self.dk
            ));
        }
        if cfg.d_model % self.ts_mha != 0 {
            return Err(format!("d_model {} not a multiple of TS_MHA {}", cfg.d_model, self.ts_mha));
        }
        if cfg.d_model % self.ts_ffn != 0 {
            return Err(format!("d_model {} not a multiple of TS_FFN {}", cfg.d_model, self.ts_ffn));
        }
        if cfg.hidden != 4 * cfg.d_model {
            return Err(format!("fabric FFN panels assume hidden = 4·d_model (got {})", cfg.hidden));
        }
        if cfg.hidden % self.ffn_col != 0 {
            return Err(format!("hidden {} not a multiple of FFN_COL {}", cfg.hidden, self.ffn_col));
        }
        if cfg.d_model > self.dmodel_max || cfg.hidden > self.hidden_max {
            return Err("topology exceeds synthesis maxima".into());
        }
        Ok(())
    }
}

/// Smallest length tier — buckets never shrink below this row count, so
/// very short requests share one program instead of fragmenting the
/// program cache.
pub const MIN_TIER: usize = 16;

/// The length-tier grid of a topology with `seq_len` rows: powers of two
/// from [`MIN_TIER`] up, always ending exactly at `seq_len` (the top
/// tier), e.g. `128 → [16, 32, 64, 128]`, `100 → [16, 32, 64, 100]`,
/// `16 → [16]`.  Bucketed program specialization and skippable attention
/// chains both quantize request length onto this grid.
pub fn length_tiers(seq_len: usize) -> Vec<usize> {
    let mut tiers = Vec::new();
    let mut t = MIN_TIER;
    while t < seq_len {
        tiers.push(t);
        t *= 2;
    }
    tiers.push(seq_len);
    tiers
}

/// The smallest tier of [`length_tiers`]`(seq_len)` covering `rows` —
/// the dispatch-time bucket of a request with `rows` live rows.
pub fn covering_bucket(rows: usize, seq_len: usize) -> usize {
    length_tiers(seq_len).into_iter().find(|&t| t >= rows).unwrap_or(seq_len)
}

/// Index of a transient device-resident value.
pub type SlotId = usize;
/// Index of a host-side scratch tensor.
pub type HostId = usize;

/// Per-topology runtime tensors: derived from the register file once per
/// programmed topology, reused across every request (they used to be
/// re-uploaded on each `run_encoder` call).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RuntimeId {
    /// Additive attention mask fencing the padded tail.
    Mask,
    /// Additive **causal** attention mask (`j <= i` within the valid
    /// prefix) — decoder masked self-attention (prefill path).
    CausalMask,
    /// One-row additive mask over memory positions (`[1, SL_MAX]`, zero on
    /// the valid prefix) — decode-step cross-attention against the cached
    /// encoder memory K/V.
    MemMaskRow,
    /// 1/sqrt(dk) attention scale scalar.
    Scale,
    /// LayerNorm column mask (1.0 on the valid prefix).
    Dmask,
    /// LayerNorm valid-column count scalar.
    Count,
    /// Zero accumulator, `[SL_MAX, DK]`.
    ZeroDk,
    /// Zero accumulator, `[SL_MAX, TS_FFN]`.
    ZeroFfn,
    /// Zero accumulator, `[SL_MAX, FFN_COL]`.
    ZeroCol,
    /// Zero accumulator, `[SL_MAX, 3*DK]` (packed QKV).
    ZeroQkv3,
    /// Additive attention mask fencing rows/keys beyond length tier `t` —
    /// the per-tier fence of a skippable attention chain.
    /// `TierMask(t)` with `t == seq_len` is value-identical to
    /// [`RuntimeId::Mask`]; smaller tiers fence tighter.
    TierMask(u16),
    /// Causal variant of [`RuntimeId::TierMask`] (decoder prefill
    /// self-attention tiers).
    TierCausalMask(u16),
}

/// Which prepared-weight tensor a [`WeightRef`] names.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WeightKind {
    /// Per-head MHA panels: `row` = head, `col` = tile.
    Wq,
    Wk,
    Wv,
    /// Per-head biases: `row` = head.
    Bq,
    Bk,
    Bv,
    /// Output-projection grid panels: `row`/`col` = panel indices.
    Wo,
    Bo,
    /// FFN2 grid panels.
    W1,
    B1,
    /// FFN3 grid panels.
    W2,
    B2,
    /// LayerNorm affine vectors.
    G1,
    B1n,
    G2,
    B2n,
    /// Packed per-head `Q|K|V` panels: `row` = head, `col` = tile.
    QkvPacked,
    BQkvPacked,
    /// Decoder cross-attention projection panels (`row` = head,
    /// `col` = tile), biases (`row` = head), output-projection grid, and
    /// the post-cross LayerNorm affine vectors.
    CWq,
    CWk,
    CWv,
    CBq,
    CBk,
    CBv,
    CWo,
    CBo,
    CG,
    CBn,
    /// Decode-step **row** weights: the full (fabric-padded) matrices the
    /// single-token datapath streams in one dispatch — per-head
    /// `[DMODEL_MAX, DK]` projections (`row` = head), the
    /// `[DMODEL_MAX, DMODEL_MAX]` output projection, and the FFN pair
    /// (`[DMODEL_MAX, HIDDEN_MAX]` / `[HIDDEN_MAX, DMODEL_MAX]`).  A 1×d
    /// activation row fits one BRAM line, so the decode path skips the
    /// SL_MAX-row panel tiling entirely (AccelTran's per-token regime).
    DWq,
    DWk,
    DWv,
    DWo,
    DW1,
    DW2,
    /// Decode-step cross-attention row weights (`row` = head for DCWq).
    DCWq,
    DCWo,
}

/// Symbolic reference into whatever weight stack is bound at replay time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WeightRef {
    pub layer: usize,
    pub kind: WeightKind,
    /// Head index or row-panel index (kind-dependent; 0 when unused).
    pub row: usize,
    /// Tile/column-panel index (0 when unused).
    pub col: usize,
}

/// One dispatch operand.
#[derive(Debug, Clone, PartialEq)]
pub enum Operand {
    Slot(SlotId),
    Weight(WeightRef),
    Runtime(RuntimeId),
    /// Caller-provided device buffer, resolved at replay time from the
    /// `externs` slice of [`replay_full`] — how the decode-step program
    /// reads the device-resident K/V cache without re-uploading it.
    /// The index is into [`TileProgram::extern_shapes`].
    Extern(usize),
}

/// Replay-time liveness predicate of a skippable dispatch: fires iff the
/// request's live row count `live` satisfies `lo < live <= hi`.  The
/// tiers of one skippable attention chain carry disjoint predicates
/// partitioning `(0, seq_len]`, so exactly one tier fires per request;
/// a dispatch whose predicate does not fire is skipped outright — no
/// operand resolution, no backend call, destination slot untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LivePred {
    /// Exclusive lower bound on the live row count.
    pub lo: usize,
    /// Inclusive upper bound — the tier's fence (its mask row count).
    pub hi: usize,
}

impl LivePred {
    pub fn fires(&self, live: usize) -> bool {
        self.lo < live && live <= self.hi
    }
}

/// One instruction of a [`TileProgram`].
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Host scratch `host` → device slot `dst`.
    Upload { host: HostId, dst: SlotId },
    /// Run artifact `artifact` over `args`, writing device slot `dst`.
    /// `out_shape` is the artifact's (fabric-fixed) output shape, recorded
    /// so shape-only backends can replay without a manifest.  `pred`
    /// makes the dispatch skippable: it executes only when the predicate
    /// fires against the replay's live row count (see [`LivePred`]).
    Dispatch {
        artifact: &'static str,
        args: Vec<Operand>,
        dst: SlotId,
        out_shape: Vec<usize>,
        pred: Option<LivePred>,
    },
    /// Device slot `src` → host scratch `host`.
    Fetch { src: SlotId, host: HostId },
    /// Column panel `[rows, width]` of host `src` (columns `c0..c0+width`)
    /// into host `dst`.
    ExtractPanel { src: HostId, c0: usize, width: usize, dst: HostId },
    /// Write host panel `src` into columns `c0..` of host `dst`.
    AssemblePanel { src: HostId, dst: HostId, c0: usize },
    /// Calibrate a per-tensor int8 scale from host `src` and upload it as
    /// scalar device slot `dst` (the only data-dependent step).
    CalibrateScale { src: HostId, dst: SlotId },
    /// Shard-boundary egress: device slot `src` is fetched into host
    /// `host` — exactly [`Step::Fetch`]'s data movement; the host is the
    /// program's output host, so the replay return value *is* the
    /// activation handed to the peer shard — and the backend's
    /// [`crate::runtime::FabricBackend::link_send`] hook is charged for
    /// moving it over the inter-fabric link.  `boundary` numbers the
    /// shard cut: shard `i` of a K-shard chain sends boundary `i`
    /// (for `i < K-1`).
    SendActivation { src: SlotId, host: HostId, boundary: usize },
    /// Shard-boundary ingress marker: the activation in host `host`
    /// (always the program's input host) arrived over the link from the
    /// peer shard's [`Step::SendActivation`].  The caller supplies it as
    /// the replay's main input, so the step moves no data; it exists so
    /// pricing backends charge
    /// [`crate::runtime::FabricBackend::link_recv`] and the verifier can
    /// match the chain's send/recv pairs.  Shard `i` receives boundary
    /// `i - 1` (for `i > 0`).
    RecvActivation { host: HostId, boundary: usize },
}

/// A lowered tile schedule: flat instruction stream + slot tables.
#[derive(Debug, Clone)]
pub struct TileProgram {
    /// The topology this program was lowered for.
    pub cfg: TnnConfig,
    /// The fabric it was lowered against.
    pub fabric: FabricConstants,
    pub steps: Vec<Step>,
    /// Shape of each host scratch slot.  Replay materializes a slot as
    /// zeros only when `host_init` demands it; slots whose first touch is
    /// a full overwrite start as empty placeholders.
    pub host_shapes: Vec<Vec<usize>>,
    /// Number of device slots.
    pub n_slots: usize,
    /// Host slot the caller writes the padded input into before replay.
    pub input_host: HostId,
    /// Additional caller-written input hosts (after `input_host`), in the
    /// order [`replay_full`] expects its `inputs` slice: the encoder
    /// memory for a decoder prefill program; the step-mask row and the
    /// position scalar for a decode-step program.  Empty for encoder
    /// programs.
    pub aux_hosts: Vec<HostId>,
    /// Host slot holding the padded output after replay.
    pub output_host: HostId,
    /// Shapes of the caller-provided device buffers [`Operand::Extern`]
    /// operands index (the device-resident K/V cache panels).  Empty for
    /// non-decode programs.
    pub extern_shapes: Vec<Vec<usize>>,
    /// Device slots kept live to the end of the replay and handed back by
    /// [`replay_full`] in this order (the freshly computed / appended K/V
    /// panels that seed or advance the cache).  Never dropped, never
    /// recycled by `CompactSlots`.
    pub export_slots: Vec<SlotId>,
    /// Device slots whose last use is step `i` (freed after executing it),
    /// computed at build time so replay memory matches the imperative
    /// engine's.
    drops: Vec<Vec<SlotId>>,
    /// Host scratch slots whose last reference is step `i` (emptied after
    /// executing it; the output slot is never dropped).
    host_drops: Vec<Vec<HostId>>,
    /// Whether a host slot must be pre-materialized as zeros: true when
    /// its first touch is a read or a partial write (`AssemblePanel` dst,
    /// whose padded tail must stay zero).  Slots first touched by a full
    /// overwrite (`Fetch`/`ExtractPanel` dst) skip the allocation+memset.
    host_init: Vec<bool>,
    /// Wave partition from `opt::ScheduleWaves`: `waves[k]` is the
    /// exclusive end index of wave `k` in `steps` (cumulative).  Members
    /// of one wave are mutually independent (see `opt::validate_waves`).
    /// Empty for an unscheduled program — strictly sequential semantics.
    waves: Vec<usize>,
}

impl TileProgram {
    /// Compute per-step slot/host drop lists from last-use analysis.
    /// Called by the builder once the stream is final.
    pub(crate) fn finalize(&mut self) {
        let mut slot_last = vec![0usize; self.n_slots];
        let mut host_last = vec![usize::MAX; self.host_shapes.len()];
        // First-touch classification for lazy host materialization: reads
        // and partial writes need a materialized tensor; full overwrites
        // (`Fetch`/`ExtractPanel` dst) do not.
        let mut host_init = vec![false; self.host_shapes.len()];
        let mut touched = vec![false; self.host_shapes.len()];
        let touch = |touched: &mut [bool], init: &mut [bool], host: HostId, needs: bool| {
            if !touched[host] {
                touched[host] = true;
                init[host] = needs;
            }
        };
        for step in &self.steps {
            match step {
                Step::Upload { host, .. } => touch(&mut touched, &mut host_init, *host, true),
                Step::CalibrateScale { src, .. } => {
                    touch(&mut touched, &mut host_init, *src, true)
                }
                Step::Fetch { host, .. } => touch(&mut touched, &mut host_init, *host, false),
                Step::ExtractPanel { src, dst, .. } => {
                    touch(&mut touched, &mut host_init, *src, true);
                    touch(&mut touched, &mut host_init, *dst, false);
                }
                Step::AssemblePanel { src, dst, .. } => {
                    touch(&mut touched, &mut host_init, *src, true);
                    touch(&mut touched, &mut host_init, *dst, true);
                }
                // A send overwrites its host wholesale (it is a Fetch with
                // link pricing); a recv's host is the caller-written input.
                Step::SendActivation { host, .. } => {
                    touch(&mut touched, &mut host_init, *host, false)
                }
                Step::RecvActivation { host, .. } => {
                    touch(&mut touched, &mut host_init, *host, false)
                }
                Step::Dispatch { .. } => {}
            }
        }
        // The caller writes the input slots before the walk starts.
        if let Some(init) = host_init.get_mut(self.input_host) {
            *init = false;
        }
        for h in &self.aux_hosts {
            if let Some(init) = host_init.get_mut(*h) {
                *init = false;
            }
        }
        for (i, step) in self.steps.iter().enumerate() {
            match step {
                Step::Upload { host, dst } => {
                    host_last[*host] = i;
                    slot_last[*dst] = i;
                }
                Step::Dispatch { args, dst, .. } => {
                    slot_last[*dst] = i;
                    for a in args {
                        if let Operand::Slot(s) = a {
                            slot_last[*s] = i;
                        }
                    }
                }
                Step::Fetch { src, host } => {
                    slot_last[*src] = i;
                    host_last[*host] = i;
                }
                Step::ExtractPanel { src, dst, .. } => {
                    host_last[*src] = i;
                    host_last[*dst] = i;
                }
                Step::AssemblePanel { src, dst, .. } => {
                    host_last[*src] = i;
                    host_last[*dst] = i;
                }
                Step::CalibrateScale { src, dst } => {
                    host_last[*src] = i;
                    slot_last[*dst] = i;
                }
                Step::SendActivation { src, host, .. } => {
                    slot_last[*src] = i;
                    host_last[*host] = i;
                }
                Step::RecvActivation { host, .. } => {
                    host_last[*host] = i;
                }
            }
        }
        // Exported slots stay live past their last in-program use: replay
        // hands them back to the caller after the final step.
        let exported: std::collections::HashSet<SlotId> =
            self.export_slots.iter().copied().collect();
        let mut drops = vec![Vec::new(); self.steps.len()];
        for (slot, last) in slot_last.iter().enumerate() {
            if !exported.contains(&slot) {
                drops[*last].push(slot);
            }
        }
        let mut host_drops = vec![Vec::new(); self.steps.len()];
        for (host, last) in host_last.iter().enumerate() {
            if host != self.output_host && *last != usize::MAX {
                host_drops[*last].push(host);
            }
        }
        self.drops = drops;
        self.host_drops = host_drops;
        self.host_init = host_init;
    }

    /// Number of artifact dispatches in one replay.
    pub fn dispatch_count(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::Dispatch { .. })).count()
    }

    /// Number of host→device transfers in one replay (uploads plus the
    /// scale upload of each calibrate step).
    pub fn upload_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s, Step::Upload { .. } | Step::CalibrateScale { .. }))
            .count()
    }

    /// Number of device→host transfers in one replay (a shard-boundary
    /// send is a fetch with link pricing, so it counts here too).
    pub fn fetch_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s, Step::Fetch { .. } | Step::SendActivation { .. }))
            .count()
    }

    /// The shard boundaries this program sends, in program order.  Empty
    /// for an unsharded program; exactly one entry for a non-final shard.
    pub fn send_boundaries(&self) -> Vec<usize> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::SendActivation { boundary, .. } => Some(*boundary),
                _ => None,
            })
            .collect()
    }

    /// The shard boundaries this program receives, in program order.
    /// Empty for an unsharded program; exactly one entry for a non-head
    /// shard.
    pub fn recv_boundaries(&self) -> Vec<usize> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::RecvActivation { boundary, .. } => Some(*boundary),
                _ => None,
            })
            .collect()
    }

    /// The artifact names dispatched, in program order.
    pub fn dispatch_sequence(&self) -> Vec<&'static str> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::Dispatch { artifact, .. } => Some(*artifact),
                _ => None,
            })
            .collect()
    }

    /// Number of waves the optimizer partitioned the stream into
    /// (0 for an unscheduled program).
    pub fn wave_count(&self) -> usize {
        self.waves.len()
    }

    /// The step range of each wave, in execution order.  Empty when the
    /// program has not been wave-scheduled.
    pub fn wave_ranges(&self) -> Vec<std::ops::Range<usize>> {
        let mut out = Vec::with_capacity(self.waves.len());
        let mut start = 0usize;
        for &end in &self.waves {
            out.push(start..end);
            start = end;
        }
        out
    }

    /// Every tier-mask runtime id the program references (both families,
    /// deduplicated, program order) — what [`upload_tier_masks`] must
    /// provide before replay.  Empty for non-tiered programs.
    pub fn tier_mask_ids(&self) -> Vec<RuntimeId> {
        let mut out = Vec::new();
        for step in &self.steps {
            if let Step::Dispatch { args, .. } = step {
                for a in args {
                    if let Operand::Runtime(
                        id @ (RuntimeId::TierMask(_) | RuntimeId::TierCausalMask(_)),
                    ) = a
                    {
                        if !out.contains(id) {
                            out.push(*id);
                        }
                    }
                }
            }
        }
        out
    }

    /// Number of skippable (predicated) dispatches in the stream.
    pub fn predicated_dispatch_count(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s, Step::Dispatch { pred: Some(_), .. }))
            .count()
    }

    /// Number of dispatches that actually execute when replayed with
    /// `live` live rows — unpredicated dispatches plus the fired tiers.
    pub fn live_dispatch_count(&self, live: usize) -> usize {
        self.steps
            .iter()
            .filter(|s| match s {
                Step::Dispatch { pred: Some(p), .. } => p.fires(live),
                Step::Dispatch { pred: None, .. } => true,
                _ => false,
            })
            .count()
    }

    /// The artifact names that actually dispatch when replayed with
    /// `live` live rows, in program order (skipped tiers elided).  For a
    /// program with no predicates this is [`Self::dispatch_sequence`].
    pub fn live_dispatch_sequence(&self, live: usize) -> Vec<&'static str> {
        self.steps
            .iter()
            .filter_map(|s| match s {
                Step::Dispatch { artifact, pred: Some(p), .. } if p.fires(live) => Some(*artifact),
                Step::Dispatch { artifact, pred: None, .. } => Some(*artifact),
                _ => None,
            })
            .collect()
    }

    /// Maximum number of dispatches sharing one wave — the peak module
    /// parallelism the schedule exposes (1 for an unscheduled program
    /// with any dispatch at all).
    pub fn max_wave_dispatches(&self) -> usize {
        if self.waves.is_empty() {
            return usize::from(self.dispatch_count() > 0);
        }
        self.wave_ranges()
            .into_iter()
            .map(|r| {
                self.steps[r].iter().filter(|s| matches!(s, Step::Dispatch { .. })).count()
            })
            .max()
            .unwrap_or(0)
    }
}

/// Resolves symbolic weight references for one backend's buffer type.
/// `PreparedStack` implements this for the PJRT executor; the cycle
/// backend binds shape-only stand-ins.
pub trait WeightSource<Buf> {
    fn weight(&self, r: &WeightRef) -> anyhow::Result<&Buf>;
}

/// The per-topology runtime tensors in one backend's buffer type.
#[derive(Debug)]
pub struct RuntimeBufs<T> {
    pub mask: T,
    pub causal_mask: T,
    pub mem_mask_row: T,
    pub scale: T,
    pub dmask: T,
    pub count: T,
    pub zero_dk: T,
    pub zero_ffn: T,
    pub zero_col: T,
    pub zero_qkv3: T,
    /// Per-tier additive masks keyed by tier row count — the fences of
    /// skippable attention chains, uploaded by [`upload_tier_masks`]
    /// (empty for non-tiered programs).
    pub tier_masks: Vec<(u16, T)>,
    /// Causal counterparts of [`RuntimeBufs::tier_masks`].
    pub tier_causal_masks: Vec<(u16, T)>,
}

impl<T> RuntimeBufs<T> {
    pub fn get(&self, id: RuntimeId) -> &T {
        match id {
            RuntimeId::Mask => &self.mask,
            RuntimeId::CausalMask => &self.causal_mask,
            RuntimeId::MemMaskRow => &self.mem_mask_row,
            RuntimeId::Scale => &self.scale,
            RuntimeId::Dmask => &self.dmask,
            RuntimeId::Count => &self.count,
            RuntimeId::ZeroDk => &self.zero_dk,
            RuntimeId::ZeroFfn => &self.zero_ffn,
            RuntimeId::ZeroCol => &self.zero_col,
            RuntimeId::ZeroQkv3 => &self.zero_qkv3,
            RuntimeId::TierMask(t) => self
                .tier_masks
                .iter()
                .find(|(k, _)| *k == t)
                .map(|(_, b)| b)
                .unwrap_or_else(|| {
                    panic!("tier mask {t} not uploaded — call upload_tier_masks first")
                }),
            RuntimeId::TierCausalMask(t) => self
                .tier_causal_masks
                .iter()
                .find(|(k, _)| *k == t)
                .map(|(_, b)| b)
                .unwrap_or_else(|| {
                    panic!("causal tier mask {t} not uploaded — call upload_tier_masks first")
                }),
        }
    }
}

/// The host-side values of the runtime tensors for `cfg` — what the
/// `Sequence`/`Embeddings` registers derive on the hardware.
pub fn runtime_tensor(id: RuntimeId, cfg: &TnnConfig, fc: &FabricConstants) -> Tensor {
    match id {
        RuntimeId::Mask => {
            let m = crate::model::reference::attention_mask(fc.sl_max, cfg.seq_len, false);
            Tensor::from_mat(&m)
        }
        RuntimeId::CausalMask => {
            let m = crate::model::reference::attention_mask(fc.sl_max, cfg.seq_len, true);
            Tensor::from_mat(&m)
        }
        RuntimeId::MemMaskRow => {
            let mut v = vec![crate::model::reference::NEG_INF; fc.sl_max];
            v[..cfg.seq_len].fill(0.0);
            Tensor::new(vec![1, fc.sl_max], v)
        }
        RuntimeId::Scale => Tensor::scalar1(1.0 / (fc.dk as f32).sqrt()),
        RuntimeId::Dmask => {
            let mut v = vec![0.0f32; fc.dmodel_max];
            v[..cfg.d_model].fill(1.0);
            Tensor::new(vec![fc.dmodel_max], v)
        }
        RuntimeId::Count => Tensor::scalar1(cfg.d_model as f32),
        RuntimeId::ZeroDk => Tensor::zeros(vec![fc.sl_max, fc.dk]),
        RuntimeId::ZeroFfn => Tensor::zeros(vec![fc.sl_max, fc.ts_ffn]),
        RuntimeId::ZeroCol => Tensor::zeros(vec![fc.sl_max, fc.ffn_col]),
        RuntimeId::ZeroQkv3 => Tensor::zeros(vec![fc.sl_max, 3 * fc.dk]),
        // Tier masks fence at the tier's row count, not the topology's
        // seq_len — the whole point of the per-tier chains.
        RuntimeId::TierMask(t) => {
            let m = crate::model::reference::attention_mask(fc.sl_max, t as usize, false);
            Tensor::from_mat(&m)
        }
        RuntimeId::TierCausalMask(t) => {
            let m = crate::model::reference::attention_mask(fc.sl_max, t as usize, true);
            Tensor::from_mat(&m)
        }
    }
}

/// Build (upload) the runtime tensor set on `backend`.  The engine calls
/// this once per topology and caches the result next to the program.
/// The four zero accumulators are topology-independent (fabric-shape
/// constants) and go through [`FabricBackend::upload_zeros`], so a
/// backend with a device zero pool shares one buffer per shape across
/// every programmed topology.
pub fn build_runtime<B: FabricBackend>(
    backend: &B,
    cfg: &TnnConfig,
    fc: &FabricConstants,
) -> anyhow::Result<RuntimeBufs<B::Buf>> {
    let up = |id: RuntimeId| backend.upload(&runtime_tensor(id, cfg, fc));
    let zeros = |id: RuntimeId| backend.upload_zeros(&runtime_tensor(id, cfg, fc).shape);
    Ok(RuntimeBufs {
        mask: up(RuntimeId::Mask)?,
        causal_mask: up(RuntimeId::CausalMask)?,
        mem_mask_row: up(RuntimeId::MemMaskRow)?,
        scale: up(RuntimeId::Scale)?,
        dmask: up(RuntimeId::Dmask)?,
        count: up(RuntimeId::Count)?,
        zero_dk: zeros(RuntimeId::ZeroDk)?,
        zero_ffn: zeros(RuntimeId::ZeroFfn)?,
        zero_col: zeros(RuntimeId::ZeroCol)?,
        zero_qkv3: zeros(RuntimeId::ZeroQkv3)?,
        tier_masks: Vec::new(),
        tier_causal_masks: Vec::new(),
    })
}

/// Upload the per-tier masks a tiered (skippable) program references,
/// extending `bufs` in place.  Idempotent per tier id; safe to call for a
/// non-tiered program (no-op).  The engine calls this once per cached
/// `(topology, bucket)` program, right after [`build_runtime`].
pub fn upload_tier_masks<B: FabricBackend>(
    backend: &B,
    bufs: &mut RuntimeBufs<B::Buf>,
    cfg: &TnnConfig,
    fc: &FabricConstants,
    ids: &[RuntimeId],
) -> anyhow::Result<()> {
    for id in ids {
        match *id {
            RuntimeId::TierMask(t) => {
                if !bufs.tier_masks.iter().any(|(k, _)| *k == t) {
                    let buf = backend.upload(&runtime_tensor(*id, cfg, fc))?;
                    bufs.tier_masks.push((t, buf));
                }
            }
            RuntimeId::TierCausalMask(t) => {
                if !bufs.tier_causal_masks.iter().any(|(k, _)| *k == t) {
                    let buf = backend.upload(&runtime_tensor(*id, cfg, fc))?;
                    bufs.tier_causal_masks.push((t, buf));
                }
            }
            _ => {}
        }
    }
    Ok(())
}

/// Column panel `[rows, width]` of a row-major 2-D tensor.
pub fn col_panel(x: &Tensor, c0: usize, width: usize) -> Tensor {
    let rows = x.shape[0];
    let cols = x.shape[1];
    let mut data = Vec::with_capacity(rows * width);
    for r in 0..rows {
        data.extend_from_slice(&x.data[r * cols + c0..r * cols + c0 + width]);
    }
    Tensor::new(vec![rows, width], data)
}

/// [`col_panel`] into a preallocated `[rows, width]` destination (pooled
/// host scratch on the request path — no allocation per panel).
pub fn col_panel_into(x: &Tensor, c0: usize, width: usize, dst: &mut Tensor) {
    let rows = x.shape[0];
    let cols = x.shape[1];
    debug_assert_eq!(dst.shape, vec![rows, width]);
    for r in 0..rows {
        dst.data[r * width..(r + 1) * width]
            .copy_from_slice(&x.data[r * cols + c0..r * cols + c0 + width]);
    }
}

/// Write `m` into the top-left corner of an (already zeroed) padded
/// tensor — `Mat::padded` into pooled scratch, no allocation.
pub fn pad_into(m: &crate::model::weights::Mat, dst: &mut Tensor) {
    let cols = dst.shape[1];
    debug_assert!(m.rows <= dst.shape[0] && m.cols <= cols, "pad_into cannot shrink");
    for r in 0..m.rows {
        dst.data[r * cols..r * cols + m.cols]
            .copy_from_slice(&m.data[r * m.cols..(r + 1) * m.cols]);
    }
}

/// Crop the top-left `rows × cols` block of a padded 2-D tensor into a
/// `Mat` — `to_mat().block(0, 0, ..)` without the intermediate clone.
pub fn crop_to_mat(t: &Tensor, rows: usize, cols: usize) -> crate::model::weights::Mat {
    let stride = t.shape[1];
    debug_assert!(rows <= t.shape[0] && cols <= stride, "crop_to_mat cannot grow");
    let mut m = crate::model::weights::Mat::zeros(rows, cols);
    for r in 0..rows {
        m.data[r * cols..(r + 1) * cols].copy_from_slice(&t.data[r * stride..r * stride + cols]);
    }
    m
}

/// Write `src` `[rows, width]` into columns `c0..` of `dst`.
pub fn set_col_panel(dst: &mut Tensor, src: &Tensor, c0: usize) {
    let rows = src.shape[0];
    let width = src.shape[1];
    let cols = dst.shape[1];
    for r in 0..rows {
        dst.data[r * cols + c0..r * cols + c0 + width]
            .copy_from_slice(&src.data[r * width..(r + 1) * width]);
    }
}

/// Replay `prog` on `backend`, binding `weights` and the per-topology
/// `runtime` tensors.  `input` must already be padded to
/// `[SL_MAX, DMODEL_MAX]`; the returned tensor has the same padded shape
/// (callers crop to the programmed topology).
pub fn replay<B: FabricBackend>(
    prog: &TileProgram,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    runtime: &RuntimeBufs<B::Buf>,
    input: Tensor,
) -> anyhow::Result<Tensor> {
    replay_with(prog, backend, weights, runtime, input, None)
}

/// [`replay`] with an optional host-scratch pool: every transient host
/// tensor (panel extracts, zero-initialized assemblies, dropped scratch)
/// is drawn from / returned to `pool`, so a steady-state request path
/// allocates nothing host-side.  Wave-scheduled programs additionally
/// fire [`FabricBackend::wave_begin`]/[`FabricBackend::wave_end`] at wave
/// boundaries; execution inside a wave stays sequential (the hooks let
/// pricing backends model the parallelism without changing numerics).
pub fn replay_with<B: FabricBackend>(
    prog: &TileProgram,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    runtime: &RuntimeBufs<B::Buf>,
    input: Tensor,
    pool: Option<&crate::runtime::pool::TensorPool>,
) -> anyhow::Result<Tensor> {
    let (out, _) = replay_full(prog, backend, weights, runtime, vec![input], &[], pool)?;
    Ok(out)
}

/// [`replay_with`] against an explicit live row count — the
/// length-adaptive entry: skippable dispatches whose tier does not cover
/// `live` are skipped, and fired tiers are priced at their tier's row
/// count by pricing backends (see [`FabricBackend::dispatch_rows`]).
pub fn replay_with_live<B: FabricBackend>(
    prog: &TileProgram,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    runtime: &RuntimeBufs<B::Buf>,
    input: Tensor,
    pool: Option<&crate::runtime::pool::TensorPool>,
    live: usize,
) -> anyhow::Result<Tensor> {
    let (out, _) =
        replay_full_adaptive(prog, backend, weights, runtime, vec![input], &[], pool, live)?;
    Ok(out)
}

/// The full replay entry point: `inputs` supplies the main input host plus
/// every [`TileProgram::aux_hosts`] slot (in order), `externs` resolves
/// [`Operand::Extern`] operands (caller-held device buffers — the K/V
/// cache), and the returned pair is the output host tensor plus the
/// [`TileProgram::export_slots`] device buffers in program order (the
/// cache panels the replay produced).
pub fn replay_full<B: FabricBackend>(
    prog: &TileProgram,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    runtime: &RuntimeBufs<B::Buf>,
    inputs: Vec<Tensor>,
    externs: &[&B::Buf],
    pool: Option<&crate::runtime::pool::TensorPool>,
) -> anyhow::Result<(Tensor, Vec<B::Buf>)> {
    // Full-length replay: the top tier of every skippable chain fires,
    // which is exactly the legacy dense behavior.
    replay_full_adaptive(prog, backend, weights, runtime, inputs, externs, pool, prog.cfg.seq_len)
}

/// [`replay_full`] against an explicit live row count `live` (clamped to
/// `[1, seq_len]`).  A predicated dispatch whose [`LivePred`] does not
/// fire is skipped outright: its operands are never resolved (they may
/// belong to an equally skipped tier) and its destination slot is left
/// untouched, because a disjoint-pred twin of another tier may own that
/// slot.  Per-step drop bookkeeping still runs for skipped steps so slot
/// lifetimes match the static analysis.
#[allow(clippy::too_many_arguments)]
pub fn replay_full_adaptive<B: FabricBackend>(
    prog: &TileProgram,
    backend: &B,
    weights: &dyn WeightSource<B::Buf>,
    runtime: &RuntimeBufs<B::Buf>,
    inputs: Vec<Tensor>,
    externs: &[&B::Buf],
    pool: Option<&crate::runtime::pool::TensorPool>,
    live: usize,
) -> anyhow::Result<(Tensor, Vec<B::Buf>)> {
    let live = live.clamp(1, prog.cfg.seq_len);
    if inputs.len() != 1 + prog.aux_hosts.len() {
        bail!(
            "replay wants 1 main + {} aux inputs, got {}",
            prog.aux_hosts.len(),
            inputs.len()
        );
    }
    for (t, h) in inputs.iter().zip(std::iter::once(&prog.input_host).chain(&prog.aux_hosts)) {
        if t.shape != prog.host_shapes[*h] {
            bail!(
                "replay input for host {h} has shape {:?}, program wants {:?}",
                t.shape,
                prog.host_shapes[*h]
            );
        }
    }
    if externs.len() != prog.extern_shapes.len() {
        bail!("program wants {} extern buffers, got {}", prog.extern_shapes.len(), externs.len());
    }
    let take_zeroed = |shape: &[usize]| match pool {
        Some(p) => p.take_zeroed(shape),
        None => Tensor::zeros(shape.to_vec()),
    };
    let recycle = |t: Tensor| {
        if let Some(p) = pool {
            p.put(t);
        }
    };
    // Materialize only the host slots whose first touch needs real zeros;
    // the rest start as empty placeholders and are assigned whole.
    let mut hosts: Vec<Tensor> = prog
        .host_shapes
        .iter()
        .enumerate()
        .map(|(i, s)| {
            if prog.host_init[i] {
                take_zeroed(s)
            } else {
                Tensor::zeros(vec![0])
            }
        })
        .collect();
    {
        let mut it = inputs.into_iter();
        hosts[prog.input_host] = it.next().expect("validated above");
        for (h, t) in prog.aux_hosts.iter().zip(it) {
            hosts[*h] = t;
        }
    }
    let mut slots: Vec<Option<B::Buf>> = Vec::with_capacity(prog.n_slots);
    slots.resize_with(prog.n_slots, || None);
    // Wave boundaries (cumulative end indices); empty → no hooks.
    let mut wave = 0usize;
    let mut wave_start = 0usize;

    for (i, step) in prog.steps.iter().enumerate() {
        if let Some(&end) = prog.waves.get(wave) {
            if i == wave_start {
                backend.wave_begin(wave, end - wave_start);
            }
        }
        match step {
            Step::Upload { host, dst } => {
                slots[*dst] = Some(backend.upload(&hosts[*host])?);
            }
            Step::Dispatch { artifact, args, dst, out_shape, pred } => {
                // Skippable dispatch: an unfired tier is skipped before
                // operand resolution (its inputs may come from equally
                // skipped steps) and leaves `dst` untouched — a fired
                // disjoint-pred twin may own the slot.
                if pred.is_some_and(|p| !p.fires(live)) {
                    // fall through to the drop bookkeeping below
                } else {
                    let mut ins: Vec<&B::Buf> = Vec::with_capacity(args.len());
                    for a in args {
                        match a {
                            Operand::Slot(s) => ins.push(
                                slots[*s]
                                    .as_ref()
                                    .ok_or_else(|| anyhow!("step {i}: slot {s} already freed"))?,
                            ),
                            Operand::Weight(w) => ins.push(weights.weight(w)?),
                            Operand::Runtime(r) => ins.push(runtime.get(*r)),
                            Operand::Extern(e) => ins.push(
                                externs
                                    .get(*e)
                                    .copied()
                                    .ok_or_else(|| anyhow!("step {i}: extern {e} out of range"))?,
                            ),
                        }
                    }
                    let rows = pred.as_ref().map(|p| p.hi);
                    let out = backend.dispatch_rows(artifact, &ins, out_shape, rows)?;
                    slots[*dst] = Some(out);
                }
            }
            Step::Fetch { src, host } => {
                let buf = slots[*src]
                    .as_ref()
                    .ok_or_else(|| anyhow!("step {i}: fetch of freed slot {src}"))?;
                let fetched = backend.fetch(buf)?;
                recycle(std::mem::replace(&mut hosts[*host], fetched));
            }
            Step::ExtractPanel { src, c0, width, dst } => {
                let panel = match pool {
                    Some(p) => {
                        let mut t = p.take_uninit(&prog.host_shapes[*dst]);
                        col_panel_into(&hosts[*src], *c0, *width, &mut t);
                        t
                    }
                    None => col_panel(&hosts[*src], *c0, *width),
                };
                recycle(std::mem::replace(&mut hosts[*dst], panel));
            }
            Step::AssemblePanel { src, dst, c0 } => {
                let (s, d) = (*src, *dst);
                if s == d {
                    bail!("step {i}: assemble with src == dst host {s}");
                }
                // Disjoint split borrow: panel source read-only, wide
                // destination mutable — no per-panel clone on the hot path.
                let (src_t, dst_t): (&Tensor, &mut Tensor) = if s < d {
                    let (left, right) = hosts.split_at_mut(d);
                    (&left[s], &mut right[0])
                } else {
                    let (left, right) = hosts.split_at_mut(s);
                    (&right[0], &mut left[d])
                };
                set_col_panel(dst_t, src_t, *c0);
            }
            Step::CalibrateScale { src, dst } => {
                let sc = crate::model::quant::calibrate_scale(&hosts[*src].data);
                slots[*dst] = Some(backend.upload(&Tensor::scalar1(sc))?);
            }
            Step::SendActivation { src, host, boundary } => {
                // Data movement is Fetch's; the link hook lets pricing
                // backends charge the inter-fabric transfer.
                let buf = slots[*src]
                    .as_ref()
                    .ok_or_else(|| anyhow!("step {i}: send of freed slot {src}"))?;
                let fetched = backend.fetch(buf)?;
                backend.link_send(fetched.data.len() * 4, *boundary);
                recycle(std::mem::replace(&mut hosts[*host], fetched));
            }
            Step::RecvActivation { host, boundary } => {
                // The activation already sits in the (caller-written)
                // input host; only the link receive is charged.
                backend.link_recv(hosts[*host].data.len() * 4, *boundary);
            }
        }
        for s in &prog.drops[i] {
            slots[*s] = None;
        }
        for h in &prog.host_drops[i] {
            recycle(std::mem::replace(&mut hosts[*h], Tensor::zeros(vec![0])));
        }
        if let Some(&end) = prog.waves.get(wave) {
            if i + 1 == end {
                backend.wave_end();
                wave_start = end;
                wave += 1;
            }
        }
    }
    // Export slots are excluded from the drop lists, so they are still
    // live here; hand them back in program order.
    let mut exports = Vec::with_capacity(prog.export_slots.len());
    for s in &prog.export_slots {
        exports.push(
            slots[*s].take().ok_or_else(|| anyhow!("export slot {s} was freed mid-replay"))?,
        );
    }
    // The output host is excluded from host_drops, so it can be moved out.
    Ok((std::mem::replace(&mut hosts[prog.output_host], Tensor::zeros(vec![0])), exports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;
    use std::cell::RefCell;

    /// A host-side mock backend: buffers are plain tensors, dispatch
    /// returns zeros of the recorded output shape.  Exercises replay
    /// mechanics (slot lifetimes, operand resolution) without PJRT.
    struct MockBackend {
        log: RefCell<Vec<String>>,
    }

    impl FabricBackend for MockBackend {
        type Buf = Tensor;
        fn upload(&self, t: &Tensor) -> anyhow::Result<Tensor> {
            Ok(t.clone())
        }
        fn dispatch(
            &self,
            artifact: &str,
            _inputs: &[&Tensor],
            out_shape: &[usize],
        ) -> anyhow::Result<Tensor> {
            self.log.borrow_mut().push(artifact.to_string());
            Ok(Tensor::zeros(out_shape.to_vec()))
        }
        fn fetch(&self, b: &Tensor) -> anyhow::Result<Tensor> {
            Ok(b.clone())
        }
    }

    struct MockWeights {
        buf: Tensor,
    }

    impl WeightSource<Tensor> for MockWeights {
        fn weight(&self, _r: &WeightRef) -> anyhow::Result<&Tensor> {
            Ok(&self.buf)
        }
    }

    fn fc() -> FabricConstants {
        FabricConstants::artifact_default()
    }

    #[test]
    fn fabric_check_mirrors_engine_constraints() {
        let f = fc();
        assert!(f.check(&presets::small_encoder(32, 1)).is_ok());
        // dk != 64
        assert!(f.check(&TnnConfig::encoder(32, 256, 8, 1)).is_err());
        // too long
        assert!(f.check(&TnnConfig::encoder(256, 256, 4, 1)).is_err());
        // too wide
        assert!(f.check(&TnnConfig::encoder(32, 1024, 16, 1)).is_err());
        // fine
        assert!(f.check(&presets::small_encoder(64, 2)).is_ok());
    }

    #[test]
    fn program_counts_follow_the_tile_schedule() {
        let f = fc();
        let cfg = presets::small_encoder(32, 2); // d=256, h=4, 2 layers
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let t_m = cfg.d_model / f.ts_mha; // 4
        let t_f = cfg.d_model / f.ts_ffn; // 2
        let t_h = cfg.hidden / f.ffn_col; // 2
        let l = cfg.enc_layers;
        // uploads: initial padded input + per-layer panel/assembly uploads
        assert_eq!(prog.upload_count(), 1 + l * (t_m + 2 * t_f + t_h + 3));
        // dispatches: per-head QKV chains + attention + FFN grids + the
        // five FFN-chain singletons (bias_add_d, residual_ln, bias_relu_h,
        // bias_add_d, residual_ln)
        let per_layer = cfg.heads * (3 * t_m + 3 + 3)
            + t_f * t_f
            + t_f * t_h
            + t_h * t_f
            + 5;
        assert_eq!(prog.dispatch_count(), l * per_layer);
        assert_eq!(prog.dispatch_sequence().len(), prog.dispatch_count());
        // the residual of layer 2 reuses layer 1's device output: no
        // full-width x upload after the first (the perf fix this IR bakes in)
        let full_uploads = prog
            .steps
            .iter()
            .filter(|s| match s {
                Step::Upload { host, .. } => {
                    prog.host_shapes[*host] == vec![f.sl_max, f.dmodel_max]
                }
                _ => false,
            })
            .count();
        assert_eq!(
            full_uploads,
            1 + 2 * l,
            "input once + assembled proj/out per layer; never the layer input x"
        );
    }

    #[test]
    fn quantized_program_adds_calibrate_and_quantize_steps() {
        let f = fc();
        let cfg = presets::small_encoder(32, 1);
        let base = ScheduleBuilder::new(f, cfg).unwrap().build();
        let quant = ScheduleBuilder::new(f, cfg).unwrap().quantized(true).build();
        assert_eq!(quant.dispatch_count(), base.dispatch_count() + cfg.enc_layers);
        assert!(quant.dispatch_sequence().contains(&"quantize"));
        assert!(!base.dispatch_sequence().contains(&"quantize"));
    }

    #[test]
    fn split_fused_and_packed_lower_to_different_streams() {
        let f = fc();
        let cfg = presets::small_encoder(32, 1);
        let split = ScheduleBuilder::new(f, cfg).unwrap().build();
        let fused =
            ScheduleBuilder::new(f, cfg).unwrap().mode(AttentionMode::Fused).build();
        let packed = ScheduleBuilder::new(f, cfg).unwrap().qkv_packed(true).build();
        assert!(split.dispatch_sequence().contains(&"qk_scores"));
        assert!(fused.dispatch_sequence().contains(&"attn_fused"));
        assert!(packed.dispatch_sequence().contains(&"mm_qkv_packed"));
        assert!(fused.dispatch_count() < split.dispatch_count());
        assert!(packed.dispatch_count() < split.dispatch_count());
    }

    #[test]
    fn replay_walks_the_whole_stream_on_a_mock_backend() {
        let f = fc();
        let cfg = presets::small_encoder(16, 1);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let backend = MockBackend { log: RefCell::new(Vec::new()) };
        let weights = MockWeights { buf: Tensor::scalar1(0.0) };
        let runtime = build_runtime(&backend, &cfg, &f).unwrap();
        let input = Tensor::zeros(vec![f.sl_max, f.dmodel_max]);
        let out = replay(&prog, &backend, &weights, &runtime, input).unwrap();
        assert_eq!(out.shape, vec![f.sl_max, f.dmodel_max]);
        let logged: Vec<&str> = backend.log.borrow().iter().map(|s| s.as_str()).collect();
        assert_eq!(logged, prog.dispatch_sequence());
    }

    #[test]
    fn replay_rejects_unpadded_input() {
        let f = fc();
        let cfg = presets::small_encoder(16, 1);
        let prog = ScheduleBuilder::new(f, cfg).unwrap().build();
        let backend = MockBackend { log: RefCell::new(Vec::new()) };
        let weights = MockWeights { buf: Tensor::scalar1(0.0) };
        let runtime = build_runtime(&backend, &cfg, &f).unwrap();
        let input = Tensor::zeros(vec![cfg.seq_len, cfg.d_model]);
        assert!(replay(&prog, &backend, &weights, &runtime, input).is_err());
    }

    #[test]
    fn col_panel_roundtrip() {
        let x = Tensor::new(vec![2, 4], vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        let p = col_panel(&x, 1, 2);
        assert_eq!(p.shape, vec![2, 2]);
        assert_eq!(p.data, vec![1.0, 2.0, 5.0, 6.0]);
        let mut y = Tensor::zeros(vec![2, 4]);
        set_col_panel(&mut y, &p, 1);
        assert_eq!(y.data, vec![0.0, 1.0, 2.0, 0.0, 0.0, 5.0, 6.0, 0.0]);
        let mut q = Tensor::zeros(vec![2, 2]);
        col_panel_into(&x, 1, 2, &mut q);
        assert_eq!(q.data, p.data, "col_panel_into must match col_panel");
    }

    #[test]
    fn pad_and_crop_match_the_mat_round_trip() {
        use crate::model::weights::Mat;
        let m = Mat { rows: 2, cols: 3, data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let mut padded = Tensor::zeros(vec![4, 5]);
        pad_into(&m, &mut padded);
        assert_eq!(Tensor::from_mat(&m.padded(4, 5)), padded);
        let back = crop_to_mat(&padded, 2, 3);
        assert_eq!(back.data, m.data);
        assert_eq!((back.rows, back.cols), (2, 3));
    }
}
