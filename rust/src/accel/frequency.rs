//! Post-route clock-frequency model (the mechanism behind Fig 5, Fig 8 and
//! Table 2's 200 → 135 MHz drop).
//!
//! Routed fmax on a near-empty device meets the 200 MHz HLS target; as
//! utilization climbs, routing congestion stretches nets.  We model fmax as
//! a piecewise-linear function of the *critical* utilization (the max of
//! DSP/LUT/BRAM fractions, LUTs slightly discounted because LUT-dense
//! regions place better than DSP columns), calibrated on the paper's two
//! anchors:
//!
//! * default build: 40 % DSP → 200 MHz (Table 2 rows 1–3)
//! * large tiles:   70 % DSP → 135 MHz (Table 2 row 4)

use super::platform::Platform;
use super::resources::ResourceEstimate;

/// Utilization knee below which the target clock closes.
pub const UTIL_KNEE: f64 = 0.45;
/// MHz lost per unit utilization beyond the knee (calibrated on Table 2's
/// large-tile row: post-synthesis 5532 DSPs = 61.3% on the U55C at 135 MHz
/// → (200−135)/(0.613−0.45) ≈ 398).
pub const SLOPE_MHZ_PER_UTIL: f64 = 398.0;
/// Routing collapses near full; clamp.
pub const FMAX_FLOOR_MHZ: f64 = 60.0;

/// Critical congestion driver.
pub fn critical_utilization(r: &ResourceEstimate) -> f64 {
    r.dsp_util.max(0.9 * r.lut_util).max(0.75 * r.bram_util)
}

/// Routed fmax for the estimate on `platform`.
pub fn fmax_mhz(platform: &Platform, r: &ResourceEstimate) -> f64 {
    let u = critical_utilization(r);
    let target = platform.target_freq_mhz;
    if u <= UTIL_KNEE {
        target
    } else {
        (target - SLOPE_MHZ_PER_UTIL * (u - UTIL_KNEE)).max(FMAX_FLOOR_MHZ)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{platform, resources, tiling::TileConfig};
    use crate::model::quant::BitWidth;
    use crate::model::TnnConfig;

    fn est(ts_mha: usize, ts_ffn: usize) -> ResourceEstimate {
        let cfg = TnnConfig::encoder(64, 768, 8, 12);
        resources::estimate(&cfg, &TileConfig::new(ts_mha, ts_ffn), BitWidth::Fixed16, &platform::u55c())
    }

    #[test]
    fn default_build_hits_target_clock() {
        let f = fmax_mhz(&platform::u55c(), &est(64, 128));
        assert_eq!(f, 200.0);
    }

    #[test]
    fn large_tiles_drop_to_135mhz_anchor() {
        // Table 2 row 4: TS=(128,192) → 135 MHz.
        let f = fmax_mhz(&platform::u55c(), &est(128, 192));
        assert!((f - 135.0).abs() < 12.0, "f = {f}");
    }

    #[test]
    fn monotone_nonincreasing_in_utilization() {
        let mut last = f64::INFINITY;
        for ts in [32, 64, 96, 128, 192, 256] {
            let f = fmax_mhz(&platform::u55c(), &est(ts, 2 * ts));
            assert!(f <= last + 1e-9, "fmax must not rise with tile size");
            last = f;
        }
    }

    #[test]
    fn floor_is_respected() {
        // absurd synthesis: giant tiles on a small device
        let cfg = TnnConfig::encoder(64, 768, 16, 12);
        let z = platform::zcu102();
        let r = resources::estimate(&cfg, &TileConfig::new(384, 768), BitWidth::Fixed16, &z);
        let f = fmax_mhz(&z, &r);
        assert!(f >= FMAX_FLOOR_MHZ);
    }
}
