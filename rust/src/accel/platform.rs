//! FPGA platform resource databases — the three boards the paper deploys
//! on (§4) plus a generic constructor for portability studies (Fig 11).
//!
//! Numbers are the vendor datasheet totals the paper's utilization
//! percentages are computed against (e.g. Table 1: ADAPTOR 3612 DSPs = 40%
//! of the U55C's 9024).

/// Off-chip memory system attached to the accelerator's AXI masters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemorySystem {
    /// HBM2 stacks (Alveo U55C: 16 GB, 32 pseudo-channels).
    Hbm2 { bandwidth_gbps: f64, channels: usize },
    /// DDR3/DDR4 DIMMs (VC707, ZCU102).
    Ddr { bandwidth_gbps: f64, channels: usize },
}

impl MemorySystem {
    /// Aggregate peak bandwidth in bytes/second.
    pub fn peak_bytes_per_sec(&self) -> f64 {
        match self {
            MemorySystem::Hbm2 { bandwidth_gbps, .. }
            | MemorySystem::Ddr { bandwidth_gbps, .. } => bandwidth_gbps * 1e9,
        }
    }

    /// Bandwidth a single AXI master port can sustain (the accelerator's
    /// loaders each own one port; §4).
    pub fn per_port_bytes_per_sec(&self) -> f64 {
        match self {
            MemorySystem::Hbm2 { bandwidth_gbps, channels } => bandwidth_gbps * 1e9 / *channels as f64,
            MemorySystem::Ddr { bandwidth_gbps, .. } => bandwidth_gbps * 1e9,
        }
    }
}

/// One FPGA device + board.
#[derive(Debug, Clone, PartialEq)]
pub struct Platform {
    pub name: String,
    pub part: String,
    /// DSP48/DSP58 slice count.
    pub dsp_total: u64,
    /// Logic LUTs.
    pub lut_total: u64,
    /// Flip-flops.
    pub ff_total: u64,
    /// BRAM in 18 Kb units (the paper's Table 2 counts BRAM18k).
    pub bram18k_total: u64,
    /// UltraRAM blocks (0 on 7-series).
    pub uram_total: u64,
    /// Fraction of LUTs usable as distributed LUTRAM (SLICEM share).
    pub lutram_fraction: f64,
    pub memory: MemorySystem,
    /// Target clock the HLS design is synthesized against (paper: 200 MHz).
    pub target_freq_mhz: f64,
    /// Static (device idle) power in watts, for the power model.
    pub static_power_w: f64,
}

impl Platform {
    /// BRAM capacity in bytes (18 Kb blocks).
    pub fn bram_bytes(&self) -> u64 {
        self.bram18k_total * 18 * 1024 / 8
    }
}

/// Xilinx Alveo U55C (UltraScale+ xcu55c-fsvh2892-2L-e) — the paper's
/// data-center card: 9024 DSPs, ~1.3 M LUTs, HBM2.
pub fn u55c() -> Platform {
    Platform {
        name: "Alveo U55C".into(),
        part: "xcu55c-fsvh2892-2L-e".into(),
        dsp_total: 9024,
        lut_total: 1_303_680,
        ff_total: 2_607_360,
        bram18k_total: 4032,
        uram_total: 960,
        lutram_fraction: 0.45,
        memory: MemorySystem::Hbm2 { bandwidth_gbps: 460.0, channels: 32 },
        target_freq_mhz: 200.0,
        static_power_w: 2.8,
    }
}

/// VC707 (Virtex-7 xc7vx485tffg1761-2): 2800 DSPs, DDR3.
pub fn vc707() -> Platform {
    Platform {
        name: "VC707".into(),
        part: "xc7vx485tffg1761-2".into(),
        dsp_total: 2800,
        lut_total: 303_600,
        ff_total: 607_200,
        bram18k_total: 2060,
        uram_total: 0,
        lutram_fraction: 0.35,
        memory: MemorySystem::Ddr { bandwidth_gbps: 12.8, channels: 1 },
        target_freq_mhz: 200.0,
        static_power_w: 1.8,
    }
}

/// ZCU102 (Zynq UltraScale+ xczu9eg-ffvb1156-2-e MPSoC): 2520 DSPs, DDR4.
pub fn zcu102() -> Platform {
    Platform {
        name: "ZCU102".into(),
        part: "xczu9eg-ffvb1156-2-e".into(),
        dsp_total: 2520,
        lut_total: 274_080,
        ff_total: 548_160,
        bram18k_total: 1824,
        uram_total: 0,
        lutram_fraction: 0.40,
        memory: MemorySystem::Ddr { bandwidth_gbps: 19.2, channels: 1 },
        target_freq_mhz: 200.0,
        static_power_w: 2.2,
    }
}

/// All boards the paper evaluates (Fig 11).
pub fn all() -> Vec<Platform> {
    vec![u55c(), zcu102(), vc707()]
}

/// Look a platform up by (case-insensitive) name prefix.
pub fn by_name(name: &str) -> Option<Platform> {
    let n = name.to_ascii_lowercase();
    all().into_iter().find(|p| p.name.to_ascii_lowercase().contains(&n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u55c_matches_paper_percentages() {
        // Table 1: ADAPTOR uses 3612 DSPs = 40% and 391k LUTs = 30%.
        let p = u55c();
        let dsp_pct = 3612.0 / p.dsp_total as f64;
        let lut_pct = 391_000.0 / p.lut_total as f64;
        assert!((dsp_pct - 0.40).abs() < 0.01, "{dsp_pct}");
        assert!((lut_pct - 0.30).abs() < 0.01, "{lut_pct}");
    }

    #[test]
    fn embedded_boards_are_smaller() {
        let (u, z, v) = (u55c(), zcu102(), vc707());
        assert!(z.dsp_total < v.dsp_total && v.dsp_total < u.dsp_total);
        assert!(z.lut_total < u.lut_total);
        // paper: "VC707 ... has slightly more resources than the ZCU102"
        assert!(v.dsp_total as f64 / z.dsp_total as f64 > 1.0);
    }

    #[test]
    fn hbm_outruns_ddr() {
        assert!(
            u55c().memory.peak_bytes_per_sec() > 10.0 * vc707().memory.peak_bytes_per_sec()
        );
    }

    #[test]
    fn by_name_matching() {
        assert_eq!(by_name("u55c").unwrap().name, "Alveo U55C");
        assert_eq!(by_name("ZCU102").unwrap().part, "xczu9eg-ffvb1156-2-e");
        assert!(by_name("stratix").is_none());
    }

    #[test]
    fn bram_capacity_sane() {
        // U55C: 4032 x 18Kb ≈ 9.3 MB of BRAM (plus URAM not counted here).
        let mb = u55c().bram_bytes() as f64 / 1e6;
        assert!(mb > 8.0 && mb < 10.0, "{mb}");
    }
}
