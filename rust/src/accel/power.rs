//! Vivado-style power estimation (the paper measures "using Vivado's power
//! estimation tool post-synthesis", Fig 10; dynamic power is constant per
//! synthesis because the fabric never changes at runtime).
//!
//! Model: `P = P_static(platform) + f_GHz · Σ c_r · N_r` with per-resource
//! switching coefficients calibrated so the paper's default U55C build
//! (3612 DSP / 2246 BRAM18k / 391 k LUT @ 200 MHz) dissipates the reported
//! 11.8 W total.

use super::platform::Platform;
use super::resources::ResourceEstimate;

/// Switching energy coefficients, watts per GHz per resource unit.
pub mod coeff {
    /// DSP48 slice at full MAC activity.
    pub const DSP_W_PER_GHZ: f64 = 0.0026;
    /// BRAM18 with both ports active.
    pub const BRAM18_W_PER_GHZ: f64 = 0.0042;
    /// Logic LUT (incl. routing share).
    pub const LUT_W_PER_GHZ: f64 = 0.000052;
    /// Flip-flop.
    pub const FF_W_PER_GHZ: f64 = 0.0000115;
}

/// Dynamic power in watts at `freq_mhz`.
pub fn dynamic_power_w(r: &ResourceEstimate, freq_mhz: f64) -> f64 {
    let f_ghz = freq_mhz / 1000.0;
    f_ghz
        * (coeff::DSP_W_PER_GHZ * r.dsp as f64
            + coeff::BRAM18_W_PER_GHZ * r.bram18k as f64
            + coeff::LUT_W_PER_GHZ * r.lut as f64
            + coeff::FF_W_PER_GHZ * r.ff as f64)
}

/// Total (static + dynamic) power in watts.
pub fn total_power_w(platform: &Platform, r: &ResourceEstimate, freq_mhz: f64) -> f64 {
    platform.static_power_w + dynamic_power_w(r, freq_mhz)
}

/// Power efficiency in GOPS/W.
pub fn gops_per_watt(gops: f64, watts: f64) -> f64 {
    gops / watts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::{platform, resources, tiling::TileConfig};
    use crate::model::quant::BitWidth;
    use crate::model::TnnConfig;

    fn default_estimate() -> ResourceEstimate {
        let cfg = TnnConfig::encoder(64, 768, 8, 12);
        resources::estimate(
            &cfg,
            &TileConfig::paper_optimum(),
            BitWidth::Fixed16,
            &platform::u55c(),
        )
    }

    #[test]
    fn calibrated_to_paper_11_8w() {
        let p = total_power_w(&platform::u55c(), &default_estimate(), 200.0);
        assert!((p - 11.8).abs() < 0.7, "total power = {p}");
    }

    #[test]
    fn power_scales_with_frequency() {
        let r = default_estimate();
        let lo = dynamic_power_w(&r, 100.0);
        let hi = dynamic_power_w(&r, 200.0);
        assert!((hi / lo - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_fabric_burns_more() {
        let cfg = TnnConfig::encoder(64, 768, 8, 12);
        let small = resources::estimate(
            &cfg,
            &TileConfig::new(32, 64),
            BitWidth::Fixed16,
            &platform::u55c(),
        );
        let big = resources::estimate(
            &cfg,
            &TileConfig::new(128, 192),
            BitWidth::Fixed16,
            &platform::u55c(),
        );
        assert!(dynamic_power_w(&big, 200.0) > dynamic_power_w(&small, 200.0));
    }

    #[test]
    fn gops_per_watt_matches_table1_adaptor_row() {
        // Table 1 Network #3 (BERT): 40 GOPS at 11.8 W → 3.39 GOPS/W.
        let eff = gops_per_watt(40.0, 11.8);
        assert!((eff - 3.39).abs() < 0.01);
    }
}
