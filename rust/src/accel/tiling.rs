//! The paper's tiling strategy (§3.9, Fig 4).
//!
//! MHA weights are tiled along the **column** axis only — the row axis is
//! already divided by the head count — giving `d_model / TS_MHA` tiles per
//! head, each visited once with partial-sum accumulation (Fig 4a).
//!
//! FFN weights are tiled along **both** axes (Fig 4b): FFN1 is visited
//! `(d_model/TS_FFN)²` times; FFN2 and FFN3 `4·(d_model/TS_FFN)²` times
//! (§3.9), with column-then-row accumulation.

use crate::model::TnnConfig;

/// Synthesis-time tile sizes (fixed; changing them = re-synthesis).
///
/// `synth_d` is the d_model the fabric was SIZED for: the FFN tile *count*
/// is a synthesis constant (`synth_d / TS_FFN`), so a smaller runtime
/// d_model narrows the per-tile width rather than dropping tiles — the
/// reading consistent with Table 2's d=512 row (see latency/mod.rs docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    pub ts_mha: usize,
    pub ts_ffn: usize,
    /// Synthesis d_model; `None` = sized exactly for the runtime model.
    pub synth_d: Option<usize>,
}

impl TileConfig {
    pub fn new(ts_mha: usize, ts_ffn: usize) -> Self {
        assert!(ts_mha > 0 && ts_ffn > 0, "tile sizes must be positive");
        Self { ts_mha, ts_ffn, synth_d: None }
    }

    /// A fabric synthesized for maxima `synth_d` (the artifact set's 768).
    pub fn for_fabric(ts_mha: usize, ts_ffn: usize, synth_d: usize) -> Self {
        let mut t = Self::new(ts_mha, ts_ffn);
        t.synth_d = Some(synth_d);
        t
    }

    /// The paper's optimum (§3.10): TS_MHA = 64, TS_FFN = 128, sized for
    /// BERT-base (d_model = 768).
    pub fn paper_optimum() -> Self {
        Self::for_fabric(64, 128, 768)
    }

    /// Number of MHA tiles: `d_model / TS_MHA` (ceil for non-divisible).
    pub fn tiles_mha(&self, d_model: usize) -> usize {
        d_model.div_ceil(self.ts_mha)
    }

    /// Number of FFN tiles per axis — a synthesis constant
    /// (`synth_d / TS_FFN`) independent of the runtime d_model.
    pub fn tiles_ffn(&self, d_model: usize) -> usize {
        self.synth_d.unwrap_or(d_model).div_ceil(self.ts_ffn)
    }

    /// Weight-buffer reload count for the MHA weight panels (§3.9: loaded
    /// `d_model/TS_MHA` times).
    pub fn mha_tile_visits(&self, cfg: &TnnConfig) -> usize {
        self.tiles_mha(cfg.d_model)
    }

    /// FFN1 module visits: both loops iterate `d_model/TS_FFN` (§3.9).
    pub fn ffn1_visits(&self, cfg: &TnnConfig) -> usize {
        let t = self.tiles_ffn(cfg.d_model);
        t * t
    }

    /// FFN2/FFN3 weight-coverage visits: `(d/TS)²` tiles of the full
    /// `TS_FFN × 4·TS_FFN` panel cover the `d × hidden` matrix exactly once
    /// (each visit's panel spans the whole hidden/t column slab).
    pub fn ffn23_visits(&self, cfg: &TnnConfig) -> usize {
        let t = self.tiles_ffn(cfg.d_model);
        t * t
    }

    /// §3.9's stated module-reuse count for FFN2/FFN3:
    /// `4·(d_model/TS_FFN)²` — the hidden/d ratio times the weight-coverage
    /// visits (the module is re-entered once per TS-wide column strip).
    pub fn ffn23_module_reuse_paper(&self, cfg: &TnnConfig) -> usize {
        let ratio = cfg.hidden.div_ceil(cfg.d_model);
        ratio * self.ffn23_visits(cfg)
    }

    /// Legality for the *execution* engine: exact divisibility (the
    /// analytical models tolerate ceil) and, for a synthesized fabric,
    /// the synthesis maxima.  The maxima check matters because
    /// [`TileConfig::tiles_ffn`] is a synthesis *constant*: with runtime
    /// `d_model > synth_d` the fixed tile count would silently under-cover
    /// the weight matrix (tiles × TS_FFN < d_model) and the engine would
    /// compute on a truncated operand.
    pub fn check_exec(&self, cfg: &TnnConfig) -> std::result::Result<(), String> {
        if let Some(synth_d) = self.synth_d {
            if cfg.d_model > synth_d {
                return Err(format!(
                    "d_model {} exceeds the synthesized maximum {} — the fabric's {} FFN tiles \
                     would cover only {} columns (re-synthesis required)",
                    cfg.d_model,
                    synth_d,
                    self.tiles_ffn(cfg.d_model),
                    self.tiles_ffn(cfg.d_model) * self.ts_ffn
                ));
            }
            if cfg.hidden > 4 * synth_d {
                return Err(format!(
                    "hidden {} exceeds the synthesized maximum {} (re-synthesis required)",
                    cfg.hidden,
                    4 * synth_d
                ));
            }
        }
        if cfg.d_model % self.ts_mha != 0 {
            return Err(format!("d_model {} % TS_MHA {} != 0", cfg.d_model, self.ts_mha));
        }
        if cfg.d_model % self.ts_ffn != 0 {
            return Err(format!("d_model {} % TS_FFN {} != 0", cfg.d_model, self.ts_ffn));
        }
        if cfg.hidden % self.ts_ffn != 0 {
            return Err(format!("hidden {} % TS_FFN {} != 0", cfg.hidden, self.ts_ffn));
        }
        Ok(())
    }
}

/// One tile visit in an iteration schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileVisit {
    /// Row-panel index into the weight matrix.
    pub row: usize,
    /// Column-panel index.
    pub col: usize,
}

/// The MHA schedule (Fig 4a): column tiles only, in order.
pub fn mha_schedule(tiles: &TileConfig, d_model: usize) -> Vec<TileVisit> {
    (0..tiles.tiles_mha(d_model)).map(|t| TileVisit { row: t, col: 0 }).collect()
}

/// The FFN schedule (Fig 4b): "results are first accumulated along the
/// columns, followed by accumulation along the rows" — row-major over
/// (col_panel, row_panel) with the row (reduction) axis inner.
pub fn ffn_schedule(row_panels: usize, col_panels: usize) -> Vec<TileVisit> {
    let mut v = Vec::with_capacity(row_panels * col_panels);
    for col in 0..col_panels {
        for row in 0..row_panels {
            v.push(TileVisit { row, col });
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn paper_optimum_tile_counts() {
        // §3.10: 12 tiles in MHA and 6 in FFN for d_model = 768.
        let t = TileConfig::paper_optimum();
        assert_eq!(t.tiles_mha(768), 12);
        assert_eq!(t.tiles_ffn(768), 6);
    }

    #[test]
    fn visit_counts_match_section_3_9() {
        let t = TileConfig::paper_optimum();
        let cfg = presets::paper_default();
        assert_eq!(t.ffn1_visits(&cfg), 36); // (768/128)^2
        assert_eq!(t.ffn23_visits(&cfg), 36); // weight coverage
        assert_eq!(t.ffn23_module_reuse_paper(&cfg), 144); // §3.9's 4·(768/128)^2
        assert_eq!(t.mha_tile_visits(&cfg), 12);
    }

    #[test]
    fn ceil_for_non_divisible_custom_encoder() {
        let t = TileConfig::new(64, 128);
        let cfg = presets::custom_encoder(); // d=200
        assert_eq!(t.tiles_mha(200), 4);
        assert!(t.check_exec(&cfg).is_err());
    }

    #[test]
    fn exec_check_passes_paper_default() {
        let t = TileConfig::paper_optimum();
        assert!(t.check_exec(&presets::paper_default()).is_ok());
        assert!(t.check_exec(&presets::shallow_transformer()).is_ok());
    }

    #[test]
    fn exec_check_rejects_runtime_wider_than_synthesis() {
        // Regression: tiles_ffn is a synthesis constant, so a runtime
        // d_model beyond synth_d used to silently under-cover the weight
        // matrix (6 tiles x 128 = 768 columns for a 1024-wide model).
        let t = TileConfig::paper_optimum(); // synth_d = 768
        let wide = TnnConfig::encoder(64, 1024, 16, 2);
        let err = t.check_exec(&wide).unwrap_err();
        assert!(err.contains("exceeds the synthesized maximum 768"), "{err}");
        assert!(err.contains("cover only 768 columns"), "{err}");
        // hidden alone can also overflow the synthesized panels
        let deep_ffn = TnnConfig { hidden: 4096, ..presets::shallow_transformer() };
        let err = t.check_exec(&deep_ffn).unwrap_err();
        assert!(err.contains("hidden 4096 exceeds"), "{err}");
        // an unsized TileConfig (synth_d = None) keeps the old behavior
        let unsized_t = TileConfig::new(64, 128);
        assert!(unsized_t.check_exec(&TnnConfig::encoder(64, 1024, 16, 2)).is_ok());
    }

    #[test]
    fn ffn_schedule_is_column_then_row() {
        let s = ffn_schedule(2, 3);
        assert_eq!(s.len(), 6);
        // first column panel's two row (reduction) steps come first
        assert_eq!(s[0], TileVisit { row: 0, col: 0 });
        assert_eq!(s[1], TileVisit { row: 1, col: 0 });
        assert_eq!(s[2], TileVisit { row: 0, col: 1 });
    }

    #[test]
    fn mha_schedule_covers_all_tiles_once() {
        let t = TileConfig::paper_optimum();
        let s = mha_schedule(&t, 768);
        assert_eq!(s.len(), 12);
        for (i, v) in s.iter().enumerate() {
            assert_eq!(v.row, i);
        }
    }

    #[test]
    #[should_panic]
    fn zero_tile_size_panics() {
        TileConfig::new(0, 128);
    }
}
