//! The AXI-Lite configuration register file (paper §3.12) — the mechanism
//! of runtime adaptivity.
//!
//! The Microblaze host writes model topology into these registers
//! (Algorithm 18 step 3); the fabric re-bounds its loops accordingly.  The
//! contract reproduced here: **writing registers never re-synthesizes**
//! (in this substrate: never re-lowers or re-compiles an artifact) — it
//! only changes loop bounds and masks fed to the fixed-shape tile
//! primitives.

use crate::model::TnnConfig;

/// Register addresses on the AXI-Lite map (§3.12's seven registers plus
/// control/status, word-addressed like a Vitis HLS s_axilite block).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum Reg {
    /// Control: bit0 = ap_start (Algorithm 18 step 13).
    Control = 0x00,
    /// Status: bit1 = ap_done (step 17 polls this).
    Status = 0x04,
    Sequence = 0x10,
    Heads = 0x14,
    LayersEnc = 0x18,
    LayersDec = 0x1C,
    Embeddings = 0x20,
    Hidden = 0x24,
    Out = 0x28,
}

/// Synthesis-time maxima the registers are validated against (the BRAM
/// buffers were sized for these; exceeding them needs a re-synthesis).
#[derive(Debug, Clone, Copy)]
pub struct SynthMaxima {
    pub seq_len: usize,
    pub heads: usize,
    pub d_model: usize,
    pub hidden: usize,
}

impl SynthMaxima {
    /// The artifact set's maxima (python/compile/configs.py).
    pub fn artifact_default() -> Self {
        SynthMaxima { seq_len: 128, heads: 12, d_model: 768, hidden: 3072 }
    }
}

/// Write-transaction record, for audit/tests of the no-resynthesis contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteEvent {
    pub reg: u32,
    pub value: u32,
}

/// The register file itself.
#[derive(Debug, Clone)]
pub struct RegisterFile {
    maxima: SynthMaxima,
    sequence: u32,
    heads: u32,
    layers_enc: u32,
    layers_dec: u32,
    embeddings: u32,
    hidden: u32,
    out: u32,
    control: u32,
    status: u32,
    /// Monotone counter of configuration generations (each successful
    /// topology write bumps it; artifact identity must NOT depend on it).
    generation: u64,
    log: Vec<WriteEvent>,
}

impl RegisterFile {
    pub fn new(maxima: SynthMaxima) -> Self {
        RegisterFile {
            maxima,
            sequence: 0,
            heads: 0,
            layers_enc: 0,
            layers_dec: 0,
            embeddings: 0,
            hidden: 0,
            out: 0,
            control: 0,
            status: 0,
            generation: 0,
            log: Vec::new(),
        }
    }

    /// AXI-Lite write; topology registers are range-checked against the
    /// synthesis maxima (hardware would silently truncate — we refuse).
    pub fn write(&mut self, reg: Reg, value: u32) -> std::result::Result<(), String> {
        let check = |v: u32, max: usize, name: &str| {
            if v as usize > max {
                Err(format!("{name}={v} exceeds synthesis maximum {max} (re-synthesis required)"))
            } else {
                Ok(())
            }
        };
        match reg {
            Reg::Sequence => {
                check(value, self.maxima.seq_len, "Sequence")?;
                self.sequence = value;
            }
            Reg::Heads => {
                check(value, self.maxima.heads, "Heads")?;
                self.heads = value;
            }
            Reg::LayersEnc => self.layers_enc = value,
            Reg::LayersDec => self.layers_dec = value,
            Reg::Embeddings => {
                check(value, self.maxima.d_model, "Embeddings")?;
                self.embeddings = value;
            }
            Reg::Hidden => {
                check(value, self.maxima.hidden, "Hidden")?;
                self.hidden = value;
            }
            Reg::Out => self.out = value,
            Reg::Control => self.control = value,
            Reg::Status => return Err("Status is read-only".into()),
        }
        self.log.push(WriteEvent { reg: reg as u32, value });
        if !matches!(reg, Reg::Control) {
            self.generation += 1;
        }
        Ok(())
    }

    pub fn read(&self, reg: Reg) -> u32 {
        match reg {
            Reg::Sequence => self.sequence,
            Reg::Heads => self.heads,
            Reg::LayersEnc => self.layers_enc,
            Reg::LayersDec => self.layers_dec,
            Reg::Embeddings => self.embeddings,
            Reg::Hidden => self.hidden,
            Reg::Out => self.out,
            Reg::Control => self.control,
            Reg::Status => self.status,
        }
    }

    /// Program a whole topology (Algorithm 18 step 3).
    pub fn program(&mut self, cfg: &TnnConfig) -> std::result::Result<(), String> {
        cfg.validate()?;
        self.write(Reg::Sequence, cfg.seq_len as u32)?;
        self.write(Reg::Heads, cfg.heads as u32)?;
        self.write(Reg::LayersEnc, cfg.enc_layers as u32)?;
        self.write(Reg::LayersDec, cfg.dec_layers as u32)?;
        self.write(Reg::Embeddings, cfg.d_model as u32)?;
        self.write(Reg::Hidden, cfg.hidden as u32)?;
        self.write(Reg::Out, cfg.d_model as u32)?;
        Ok(())
    }

    /// Reconstruct the programmed topology.
    pub fn current_config(&self) -> TnnConfig {
        TnnConfig {
            seq_len: self.sequence as usize,
            heads: self.heads as usize,
            d_model: self.embeddings as usize,
            hidden: self.hidden as usize,
            enc_layers: self.layers_enc as usize,
            dec_layers: self.layers_dec as usize,
        }
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn write_log(&self) -> &[WriteEvent] {
        &self.log
    }

    pub fn maxima(&self) -> SynthMaxima {
        self.maxima
    }

    /// ap_start / ap_done handshake (Algorithm 18 steps 13–18).
    pub fn start(&mut self) {
        self.control |= 1;
        self.status &= !0b10;
    }

    pub fn set_done(&mut self) {
        self.status |= 0b10;
        self.control &= !1;
    }

    pub fn is_done(&self) -> bool {
        self.status & 0b10 != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn program_and_readback_roundtrip() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        let cfg = presets::small_encoder(64, 4);
        rf.program(&cfg).unwrap();
        assert_eq!(rf.current_config(), cfg);
        assert_eq!(rf.read(Reg::Embeddings), 256);
    }

    #[test]
    fn exceeding_synthesis_maxima_is_refused() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        assert!(rf.write(Reg::Sequence, 129).is_err());
        assert!(rf.write(Reg::Embeddings, 1024).is_err());
        assert!(rf.write(Reg::Heads, 16).is_err());
        // nothing was committed
        assert_eq!(rf.read(Reg::Sequence), 0);
    }

    #[test]
    fn reprogramming_needs_no_resynthesis() {
        // generation changes, synthesis maxima (artifact identity) do not.
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        rf.program(&presets::small_encoder(64, 4)).unwrap();
        let g1 = rf.generation();
        let m1 = rf.maxima();
        rf.program(&presets::bert_base(64)).unwrap();
        assert!(rf.generation() > g1);
        let m2 = rf.maxima();
        assert_eq!(
            (m1.seq_len, m1.d_model, m1.heads, m1.hidden),
            (m2.seq_len, m2.d_model, m2.heads, m2.hidden),
            "maxima (= synthesized fabric) must be untouched by reprogramming"
        );
    }

    #[test]
    fn status_is_read_only() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        assert!(rf.write(Reg::Status, 1).is_err());
    }

    #[test]
    fn start_done_handshake() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        rf.start();
        assert!(!rf.is_done());
        assert_eq!(rf.read(Reg::Control) & 1, 1);
        rf.set_done();
        assert!(rf.is_done());
        assert_eq!(rf.read(Reg::Control) & 1, 0);
    }

    #[test]
    fn write_log_records_programming_sequence() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        rf.program(&presets::small_encoder(32, 2)).unwrap();
        assert_eq!(rf.write_log().len(), 7);
        assert_eq!(rf.write_log()[0].reg, Reg::Sequence as u32);
    }

    #[test]
    fn bert_fits_artifact_maxima() {
        let mut rf = RegisterFile::new(SynthMaxima::artifact_default());
        assert!(rf.program(&presets::bert_base(128)).is_ok());
        assert!(rf.program(&presets::bert_base(64)).is_ok());
    }
}
