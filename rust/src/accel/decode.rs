//! Autoregressive decoder execution: the **KV cache** and the layout
//! contract between the decoder programs and the serving engine.
//!
//! A generation runs as two program flavors per topology (both lowered by
//! `accel::schedule::builder` and cached/optimized like any other
//! `TileProgram`):
//!
//! * **prefill** — the whole prompt through every decoder layer.  Each
//!   layer's self-attention K/V panels (and, for seq2seq topologies, the
//!   cross-attention K/V projected once from the encoder memory) are
//!   *exported* from the replay as device-resident buffers and become the
//!   initial [`KvCache`];
//! * **decode-step** — one token row.  The cache panels enter the program
//!   as `Operand::Extern` device buffers (no re-upload), the new token's
//!   K/V row is appended on-device (`kv_append`), and the appended panels
//!   are exported back to advance the cache.
//!
//! The cache is generic over the backend buffer type so the same machinery
//! serves the PJRT executor (`DeviceTensor`), the cycle backend (shapes)
//! and the artifact-free property-test backends (host tensors).
//!
//! [`ExternLayout`] is the single source of truth for the order in which
//! cache panels cross the program boundary; the builder and the cache both
//! derive their indices from it.

use anyhow::bail;

use crate::model::TnnConfig;
use crate::runtime::Tensor;

/// Canonical ordering of cache panels across the program boundary.
///
/// Extern (and prefill-export) order: for each decoder layer, per head
/// `[self_k, self_v]`, then — iff the topology has an encoder stack
/// (cross-attention) — per head `[cross_k, cross_v]`.  Decode-step
/// exports cover only the self entries (cross K/V are step-invariant),
/// in the same per-layer, per-head `[k, v]` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExternLayout {
    pub layers: usize,
    pub heads: usize,
    /// Whether the topology carries cross-attention (seq2seq).
    pub cross: bool,
}

impl ExternLayout {
    pub fn of(cfg: &TnnConfig) -> Self {
        ExternLayout { layers: cfg.dec_layers, heads: cfg.heads, cross: cfg.enc_layers > 0 }
    }

    /// Cache panels per decoder layer.
    pub fn per_layer(&self) -> usize {
        self.heads * 2 * if self.cross { 2 } else { 1 }
    }

    /// Total cache panels (= extern count of the decode-step program and
    /// export count of the prefill program).
    pub fn total(&self) -> usize {
        self.layers * self.per_layer()
    }

    /// Panels a decode-step exports (self K/V only).
    pub fn step_exports(&self) -> usize {
        self.layers * self.heads * 2
    }

    pub fn self_k(&self, layer: usize, head: usize) -> usize {
        layer * self.per_layer() + head * 2
    }

    pub fn self_v(&self, layer: usize, head: usize) -> usize {
        self.self_k(layer, head) + 1
    }

    /// Cross-attention K panel index.  Asking a self-attention-only
    /// layout is a typed error in every build profile (a `debug_assert`
    /// here used to let release builds silently alias a self panel).
    pub fn cross_k(&self, layer: usize, head: usize) -> Result<usize, NoCrossPanels> {
        if !self.cross {
            return Err(NoCrossPanels);
        }
        Ok(layer * self.per_layer() + self.heads * 2 + head * 2)
    }

    pub fn cross_v(&self, layer: usize, head: usize) -> Result<usize, NoCrossPanels> {
        Ok(self.cross_k(layer, head)? + 1)
    }
}

/// Cross-attention panels were requested from a layout whose topology has
/// no encoder stack (no cross-attention, hence no cross K/V in the cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NoCrossPanels;

impl std::fmt::Display for NoCrossPanels {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("cross-attention K/V panels requested from a self-attention-only cache layout")
    }
}

impl std::error::Error for NoCrossPanels {}

/// Device-resident K/V panels for one in-flight generation.
///
/// Every panel is fabric-shaped (`[SL_MAX, DK]`); `len` is the number of
/// valid rows (prompt + tokens generated so far) — rows beyond it hold
/// projections of padding and are fenced by the step mask.
pub struct KvCache<B> {
    layout: ExternLayout,
    /// Valid rows: the next decode-step appends at position `len`.
    pub len: usize,
    bufs: Vec<B>,
}

impl<B> KvCache<B> {
    /// Build the cache from a prefill replay's exports (which arrive in
    /// [`ExternLayout`] order by construction).
    pub fn from_prefill(cfg: &TnnConfig, exports: Vec<B>, prompt_len: usize) -> anyhow::Result<Self> {
        let layout = ExternLayout::of(cfg);
        if exports.len() != layout.total() {
            bail!(
                "prefill exported {} K/V panels, topology wants {}",
                exports.len(),
                layout.total()
            );
        }
        Ok(KvCache { layout, len: prompt_len, bufs: exports })
    }

    pub fn layout(&self) -> ExternLayout {
        self.layout
    }

    /// The extern slice for a decode-step replay, in layout order.
    pub fn externs(&self) -> Vec<&B> {
        self.bufs.iter().collect()
    }

    /// Fold a decode-step's exports (the appended self K/V panels) back
    /// in and advance the valid length by one token.
    pub fn apply_step(&mut self, exports: Vec<B>) -> anyhow::Result<()> {
        if exports.len() != self.layout.step_exports() {
            bail!(
                "decode step exported {} panels, cache wants {}",
                exports.len(),
                self.layout.step_exports()
            );
        }
        let mut it = exports.into_iter();
        for layer in 0..self.layout.layers {
            for head in 0..self.layout.heads {
                self.bufs[self.layout.self_k(layer, head)] = it.next().expect("sized above");
                self.bufs[self.layout.self_v(layer, head)] = it.next().expect("sized above");
            }
        }
        self.len += 1;
        Ok(())
    }
}

/// The step-mask row for a query at position `pos`: additive zero on keys
/// `j <= pos`, `NEG_INF` beyond — the per-token slice of the causal mask,
/// rebuilt each step because it depends on the generation position.
pub fn step_mask_row(sl_max: usize, pos: usize) -> Tensor {
    let mut v = vec![crate::model::reference::NEG_INF; sl_max];
    v[..=pos.min(sl_max - 1)].fill(0.0);
    Tensor::new(vec![1, sl_max], v)
}

/// The position scalar the `kv_append` artifact consumes.
pub fn position_tensor(pos: usize) -> Tensor {
    Tensor::scalar1(pos as f32)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq2seq(layers: usize, heads: usize) -> TnnConfig {
        TnnConfig {
            seq_len: 32,
            heads,
            d_model: heads * 64,
            hidden: 4 * heads * 64,
            enc_layers: 1,
            dec_layers: layers,
        }
    }

    #[test]
    fn layout_indices_are_dense_and_disjoint() {
        let l = ExternLayout::of(&seq2seq(3, 4));
        assert!(l.cross);
        assert_eq!(l.per_layer(), 16);
        assert_eq!(l.total(), 48);
        let mut seen = std::collections::HashSet::new();
        for layer in 0..3 {
            for head in 0..4 {
                for idx in [
                    l.self_k(layer, head),
                    l.self_v(layer, head),
                    l.cross_k(layer, head).unwrap(),
                    l.cross_v(layer, head).unwrap(),
                ] {
                    assert!(idx < l.total());
                    assert!(seen.insert(idx), "index {idx} reused");
                }
            }
        }
        assert_eq!(seen.len(), l.total());
    }

    #[test]
    fn decoder_only_layout_has_no_cross_entries() {
        let mut cfg = seq2seq(2, 2);
        cfg.enc_layers = 0;
        let l = ExternLayout::of(&cfg);
        assert!(!l.cross);
        assert_eq!(l.total(), 8);
        assert_eq!(l.step_exports(), l.total());
    }

    #[test]
    fn cross_panels_from_a_self_only_layout_are_a_typed_error() {
        let mut cfg = seq2seq(2, 2);
        cfg.enc_layers = 0;
        let l = ExternLayout::of(&cfg);
        assert_eq!(l.cross_k(0, 0), Err(NoCrossPanels));
        assert_eq!(l.cross_v(1, 1), Err(NoCrossPanels));
        assert!(NoCrossPanels.to_string().contains("self-attention-only"));
    }

    #[test]
    fn cache_round_trips_prefill_and_steps() {
        let cfg = seq2seq(2, 2);
        let l = ExternLayout::of(&cfg);
        let bufs: Vec<u32> = (0..l.total() as u32).collect();
        let mut cache = KvCache::from_prefill(&cfg, bufs, 5).unwrap();
        assert_eq!(cache.len, 5);
        assert_eq!(cache.externs().len(), l.total());
        // a step replaces exactly the self entries
        let step: Vec<u32> = (100..100 + l.step_exports() as u32).collect();
        cache.apply_step(step).unwrap();
        assert_eq!(cache.len, 6);
        let ext = cache.externs();
        assert_eq!(*ext[l.self_k(0, 0)], 100);
        assert_eq!(*ext[l.self_v(0, 0)], 101);
        // cross entries untouched
        let ck = l.cross_k(0, 0).unwrap();
        assert_eq!(*ext[ck], ck as u32);
        // wrong sizes are refused
        assert!(cache.apply_step(vec![1, 2]).is_err());
        assert!(KvCache::from_prefill(&cfg, vec![0u32; 3], 1).is_err());
    }

    #[test]
    fn step_mask_row_fences_the_future() {
        let m = step_mask_row(8, 3);
        assert_eq!(m.shape, vec![1, 8]);
        assert_eq!(m.data[0], 0.0);
        assert_eq!(m.data[3], 0.0);
        assert!(m.data[4] < -1e8);
        assert_eq!(position_tensor(3).data, vec![3.0]);
    }
}
