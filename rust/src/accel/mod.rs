//! The FPGA fabric substitute — every hardware element the paper's
//! evaluation ran on, rebuilt as a faithful software substrate
//! (DESIGN.md §Substitutions).
//!
//! * [`platform`] — Alveo U55C / VC707 / ZCU102 resource databases.
//! * [`tiling`] — the paper's tiling geometry (Fig 4a/4b, §3.9–3.10).
//! * [`resources`] — analytical DSP (Eq 8), BRAM (Eq 25) and LUT models.
//! * [`frequency`] — post-route clock vs utilization (Fig 5/8 mechanism).
//! * [`power`] — Vivado-style static+dynamic power estimation (Fig 10).
//! * [`latency`] — the paper's closed-form latency model (Eqs 9–39).
//! * [`sim`] — independent cycle-level simulator (Table 2 "experimental").
//! * [`schedule`] — the TileProgram IR: the §3.9 tile schedules lowered to
//!   a flat instruction stream, replayed by pluggable fabric backends.
//! * [`decode`] — autoregressive decoder execution: the device-resident
//!   KV cache and the prefill/decode-step program boundary contract.
//! * [`registers`] — the AXI-Lite runtime configuration register file.
//! * [`roofline`] — compute/memory bounds and attained performance (Fig 12).

pub mod decode;
pub mod frequency;
pub mod latency;
pub mod platform;
pub mod power;
pub mod registers;
pub mod resources;
pub mod roofline;
pub mod schedule;
pub mod sim;
pub mod tiling;

use crate::model::TnnConfig;
use platform::Platform;
use tiling::TileConfig;

/// A "synthesis" of ADAPTOR: one platform + one tile configuration +
/// datapath width, fixed for the lifetime of the fabric (§3.10: "the tile
/// size must be set before synthesis").  Everything else is runtime.
#[derive(Debug, Clone)]
pub struct Synthesis {
    pub platform: Platform,
    pub tiles: TileConfig,
    pub bit_width: crate::model::quant::BitWidth,
    /// Maximum topology the BRAM buffers were sized for.
    pub max_config: TnnConfig,
}

impl Synthesis {
    /// The paper's default build (§6): U55C, TS_MHA=64, TS_FFN=128,
    /// fixed-point 16, BERT-base maxima.
    pub fn paper_default() -> Self {
        Synthesis {
            platform: platform::u55c(),
            tiles: TileConfig::paper_optimum(),
            bit_width: crate::model::quant::PAPER_DEFAULT,
            max_config: crate::model::presets::bert_base(64),
        }
    }

    /// Resource estimate for running `cfg` on this synthesis.
    pub fn resources(&self, cfg: &TnnConfig) -> resources::ResourceEstimate {
        resources::estimate(cfg, &self.tiles, self.bit_width, &self.platform)
    }

    /// Post-route frequency for `cfg` on this synthesis.
    pub fn frequency_mhz(&self, cfg: &TnnConfig) -> f64 {
        let r = self.resources(cfg);
        frequency::fmax_mhz(&self.platform, &r)
    }

    /// Feasibility: does the synthesized fabric fit the device?
    pub fn check_fit(&self, cfg: &TnnConfig) -> std::result::Result<(), String> {
        let r = self.resources(cfg);
        r.check_fit(&self.platform)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_fits_u55c() {
        let s = Synthesis::paper_default();
        let cfg = crate::model::presets::paper_default();
        assert!(s.check_fit(&cfg).is_ok());
        let f = s.frequency_mhz(&cfg);
        assert!(f > 100.0 && f <= 300.0, "{f}");
    }
}
