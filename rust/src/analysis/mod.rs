//! Design-space exploration and evaluation-artifact regeneration.
//!
//! [`sweep`] runs the paper's parameter sweeps (tile sizes, head counts)
//! over the analytical + simulated models; [`report`] renders every table
//! and figure of the paper's evaluation section as text tables/CSV, the
//! `adaptor report` CLI and the criterion benches both drive it.

pub mod report;
pub mod sweep;

/// Simple fixed-width text table builder used by every report.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("| a  | bbbb |"));
        assert!(s.lines().count() == 3);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = TextTable::new(&["h1", "h2"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "h1,h2\n1,2\n");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = TextTable::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
