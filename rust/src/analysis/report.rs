//! Regenerators for every table and figure in the paper's evaluation
//! (§6): each function returns the rendered text block; [`write_all`]
//! drops them under `reports/` (one `.txt` + one `.csv` per artifact).
//!
//! Paper-vs-reproduced commentary lives in EXPERIMENTS.md; these renderers
//! print the *measured* (substrate) numbers next to the paper's where the
//! paper's are data (Table 1, Fig 10).

use std::fmt::Write as _;

use super::sweep::{self, DesignPoint};
use super::TextTable;
use crate::accel::platform::{self, Platform};
use crate::accel::schedule::{AttentionMode, FabricConstants, OptLevel};
use crate::accel::sim::cycle;
use crate::accel::{frequency, latency, power, resources, roofline, tiling::TileConfig};
use crate::baselines::{literature, nonadaptive};
use crate::model::quant::BitWidth;
use crate::model::{presets, TnnConfig};

const BW: BitWidth = BitWidth::Fixed16;

fn fmt_f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Fig 5 — frequency and normalized latency vs tile counts.
pub fn fig05() -> (String, TextTable) {
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let pts = sweep::tile_sweep(&cfg, &platform::u55c(), BW);
    let min_lat = pts.iter().map(|p| p.latency_ms).fold(f64::INFINITY, f64::min);
    let mut t = TextTable::new(&[
        "tiles_mha", "tiles_ffn", "ts_mha", "ts_ffn", "freq_mhz", "latency_ms", "latency_norm",
    ]);
    for p in &pts {
        t.row(vec![
            p.tiles_mha.to_string(),
            p.tiles_ffn.to_string(),
            p.ts_mha.to_string(),
            p.ts_ffn.to_string(),
            fmt_f(p.freq_mhz, 1),
            fmt_f(p.latency_ms, 3),
            fmt_f(p.latency_ms / min_lat, 3),
        ]);
    }
    let best = sweep::best_by_latency(&pts).unwrap();
    let mut s = String::new();
    let _ = writeln!(s, "Fig 5 — choosing the optimum tile size (BERT-ish d=768, SL=64, U55C)");
    let _ = writeln!(
        s,
        "paper: optimum at 12 MHA tiles / 6 FFN tiles, 200 MHz.  reproduced optimum: {} / {} at {:.0} MHz\n",
        best.tiles_mha, best.tiles_ffn, best.freq_mhz
    );
    s.push_str(&t.render());
    (s, t)
}

/// Fig 8 — performance and resources vs attention heads.
pub fn fig08() -> (String, TextTable) {
    let base = TnnConfig::encoder(64, 768, 8, 12);
    let pts = sweep::heads_sweep(&base, &platform::u55c(), BW);
    let min_lat = pts.iter().map(|p| p.latency_ms).fold(f64::INFINITY, f64::min);
    let mut t = TextTable::new(&["heads", "freq_mhz", "latency_norm", "dsp", "lut_k"]);
    for p in &pts {
        t.row(vec![
            p.heads.to_string(),
            fmt_f(p.freq_mhz, 1),
            fmt_f(p.latency_ms / min_lat, 3),
            p.dsp.to_string(),
            fmt_f(p.lut as f64 / 1e3, 0),
        ]);
    }
    let best = pts
        .iter()
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .map(|p| p.heads)
        .unwrap_or(0);
    let mut s = String::new();
    let _ = writeln!(s, "Fig 8 — performance & resource utilization vs attention heads (U55C)");
    let _ = writeln!(s, "paper: optimal 6–10 heads; frequency decays beyond.  reproduced optimum: {best} heads\n");
    s.push_str(&t.render());
    (s, t)
}

/// Fig 9 — DSP/LUT/BRAM utilization vs tile sizes.
pub fn fig09() -> (String, TextTable) {
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let p = platform::u55c();
    let mut t = TextTable::new(&["ts_mha", "ts_ffn", "dsp_pct", "lut_pct", "bram_pct", "fits"]);
    for (tm, tf) in [(32, 64), (64, 96), (64, 128), (64, 192), (96, 192), (128, 192), (128, 256), (192, 384)] {
        let tiles = TileConfig::for_fabric(tm, tf, 768);
        let r = resources::estimate(&cfg, &tiles, BW, &p);
        t.row(vec![
            tm.to_string(),
            tf.to_string(),
            fmt_f(100.0 * r.dsp_util, 1),
            fmt_f(100.0 * r.lut_util, 1),
            fmt_f(100.0 * r.bram_util, 1),
            r.check_fit(&p).is_ok().to_string(),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Fig 9 — utilization vs tile size (U55C; DSPs saturate first: compute-bound)");
    s.push_str(&t.render());
    (s, t)
}

/// The substrate-measured ADAPTOR row for a workload (GOPS from the
/// latency model at the build's frequency, power from the power model).
pub fn adaptor_row(cfg: &TnnConfig) -> (f64, f64, resources::ResourceEstimate, f64) {
    let synth_cfg = TnnConfig::encoder(64, 768, 8, 12); // fixed synthesis
    let p = platform::u55c();
    let tiles = TileConfig::paper_optimum();
    let r = resources::estimate(&synth_cfg, &tiles, BW, &p);
    let f = frequency::fmax_mhz(&p, &r);
    let lat = latency::model_latency(cfg, &tiles);
    let gops = lat.gops_at(cfg, f);
    let watts = power::total_power_w(&p, &r, f);
    (gops, watts, r, f)
}

/// Fig 10 — cross-platform power comparison.
pub fn fig10() -> (String, TextTable) {
    let mut t = TextTable::new(&["model", "device", "kind", "power_w", "gops_per_w", "source"]);
    for pt in literature::fig10() {
        t.row(vec![
            pt.model.to_string(),
            pt.device.to_string(),
            format!("{:?}", pt.kind),
            fmt_f(pt.power_w, 1),
            fmt_f(pt.gops_per_w, 2),
            if pt.verbatim { pt.citation.to_string() } else { format!("{} (ratio-derived)", pt.citation) },
        ]);
    }
    // substrate-measured ADAPTOR rows next to the paper's anchors
    for (name, cfg) in [
        ("BERT", presets::bert_base(64)),
        ("Custom Encoder", presets::custom_encoder_4l()),
        ("Shallow Transformer", presets::shallow_transformer()),
    ] {
        let (gops, watts, _, _) = adaptor_row(&cfg);
        t.row(vec![
            name.to_string(),
            "ADAPTOR-RS (substrate)".to_string(),
            "Fpga".to_string(),
            fmt_f(watts, 1),
            fmt_f(gops / watts, 2),
            "(this repo)".to_string(),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Fig 10 — power consumption & power efficiency across platforms");
    let _ = writeln!(s, "paper claims reproduced in data: ADAPTOR 1.2x vs K80, 2.87x vs i7-8700K (BERT)\n");
    s.push_str(&t.render());
    (s, t)
}

/// Fig 11 — portability across U55C / ZCU102 / VC707.
pub fn fig11() -> (String, TextTable) {
    let cfg = presets::custom_encoder(); // d=200, h=3, N=2, SL=64
    let mut t = TextTable::new(&[
        "platform", "ts_mha", "ts_ffn", "dsp_pct", "lut_pct", "freq_mhz", "latency_ms",
    ]);
    // the paper's chosen per-platform tile sizes
    let choices: [(&Platform, usize, usize); 3] = [
        (&platform::u55c(), 200, 200),
        (&platform::zcu102(), 25, 50),
        (&platform::vc707(), 50, 50),
    ];
    let mut rows: Vec<(String, f64)> = Vec::new();
    for (p, tm, tf) in choices {
        let tiles = TileConfig::for_fabric(tm, tf, cfg.d_model);
        let r = resources::estimate(&cfg, &tiles, BW, p);
        let f = frequency::fmax_mhz(p, &r);
        let lat = latency::model_latency(&cfg, &tiles).ms_at(f);
        rows.push((p.name.clone(), lat));
        t.row(vec![
            p.name.clone(),
            tm.to_string(),
            tf.to_string(),
            fmt_f(100.0 * r.dsp_util, 1),
            fmt_f(100.0 * r.lut_util, 1),
            fmt_f(f, 1),
            fmt_f(lat, 3),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Fig 11 — portability: custom encoder (d=200, h=3, N=2, SL=64) per platform");
    let _ = writeln!(
        s,
        "paper: U55C fastest (max tiles), ZCU102/VC707 fit with reduced tiles at ~100% util.\nreproduced order: {}\n",
        rows.iter().map(|(n, l)| format!("{n}={l:.3}ms")).collect::<Vec<_>>().join("  ")
    );
    s.push_str(&t.render());
    (s, t)
}

/// Fig 12 — roofline.
pub fn fig12() -> (String, TextTable) {
    let p = platform::u55c();
    let tiles = TileConfig::paper_optimum();
    let workloads = [
        ("BERT (TS 64/192)", presets::bert_base(64)),
        ("custom encoder", presets::custom_encoder_4l()),
        ("shallow transformer", presets::shallow_transformer()),
    ];
    let pts: Vec<(&str, TnnConfig, f64)> = workloads
        .iter()
        .map(|(n, c)| {
            let lat = latency::model_latency(c, &tiles);
            (*n, *c, lat.gops_at(c, 200.0))
        })
        .collect();
    let r = roofline::roofline(&p, &tiles, 200.0, BW.bytes(), &pts);
    let mut t = TextTable::new(&["point", "oi_ops_per_byte", "attained_gops", "bound_gops", "regime"]);
    for pt in &r.points {
        t.row(vec![
            pt.name.clone(),
            fmt_f(pt.oi, 1),
            fmt_f(pt.attained_gops, 1),
            fmt_f(pt.bound_gops, 1),
            if pt.oi < r.ridge_oi { "memory-bound" } else { "compute-bound" }.to_string(),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Fig 12 — roofline (U55C synthesis)");
    let _ = writeln!(
        s,
        "compute bound: {:.1} GOPS (paper: 53 GOPS = 0.053 TOPS); stream bound: {:.2} GB/s (paper's axis typo'd as 200 kB/s); ridge OI: {:.1}\n",
        r.peak_gops, r.stream_gbps, r.ridge_oi
    );
    s.push_str(&t.render());
    (s, t)
}

/// Fig 13 — GOPS vs DSP utilization across tile combinations.
pub fn fig13() -> (String, TextTable) {
    let cfg = TnnConfig::encoder(64, 768, 8, 12);
    let pts = sweep::tile_sweep(&cfg, &platform::u55c(), BW);
    let mut sorted: Vec<&DesignPoint> = pts.iter().collect();
    sorted.sort_by(|a, b| a.dsp_util.partial_cmp(&b.dsp_util).unwrap());
    let mut t = TextTable::new(&["dsp_util_pct", "ts_mha", "ts_ffn", "freq_mhz", "gops"]);
    for p in sorted {
        t.row(vec![
            fmt_f(100.0 * p.dsp_util, 1),
            p.ts_mha.to_string(),
            p.ts_ffn.to_string(),
            fmt_f(p.freq_mhz, 1),
            fmt_f(p.gops, 1),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Fig 13 — effect of DSP utilization on GOPS across tile combinations");
    let _ = writeln!(s, "paper: GOPS rises with DSP use, then frequency decay bends it back down\n");
    s.push_str(&t.render());
    (s, t)
}

/// Table 1 — FPGA-accelerator comparison (paper rows + substrate rows).
pub fn table1() -> (String, TextTable) {
    let mut t = TextTable::new(&[
        "network", "accelerator", "dsp", "lut_k", "gops", "power_w", "gops/kdsp", "gops/klut", "gops/w", "sparsity",
    ]);
    for r in literature::table1() {
        t.row(vec![
            r.network.to_string(),
            format!("{} {}", r.accelerator, r.citation),
            r.dsp.to_string(),
            fmt_f(r.lut as f64 / 1e3, 0),
            fmt_f(r.gops, 1),
            r.power_w.map(|p| fmt_f(p, 1)).unwrap_or_else(|| "-".into()),
            fmt_f(r.gops_per_kdsp(), 2),
            fmt_f(r.gops_per_klut(), 3),
            r.gops_per_watt().map(|p| fmt_f(p, 2)).unwrap_or_else(|| "-".into()),
            r.sparsity.map(|s| format!("{:.0}%", 100.0 * s)).unwrap_or_else(|| "-".into()),
        ]);
    }
    for (net, cfg) in [
        ("Shallow Transformer", presets::shallow_transformer()),
        ("Custom Transformer Encoder", presets::custom_encoder_4l()),
        ("BERT", presets::bert_base(64)),
    ] {
        let (gops, watts, r, _) = adaptor_row(&cfg);
        t.row(vec![
            net.to_string(),
            "ADAPTOR-RS (substrate)".to_string(),
            r.dsp.to_string(),
            fmt_f(r.lut as f64 / 1e3, 0),
            fmt_f(gops, 1),
            fmt_f(watts, 1),
            fmt_f(gops / r.dsp as f64 * 1e3, 2),
            fmt_f(gops / r.lut as f64 * 1e3, 3),
            fmt_f(gops / watts, 2),
            "0%".to_string(),
        ]);
    }
    let mut s = String::new();
    let _ = writeln!(s, "Table 1 — comparison with FPGA accelerators (paper rows verbatim + substrate rows)");
    s.push_str(&t.render());
    (s, t)
}

/// Table 2 — analytical vs (simulated-)experimental validation.
pub fn table2() -> (String, TextTable) {
    let p = platform::u55c();
    let rows = [
        (64usize, 768usize, 8usize, 64usize, 128usize),
        (128, 768, 8, 64, 128),
        (64, 512, 8, 64, 128),
        (64, 768, 8, 128, 192),
    ];
    let mut t = TextTable::new(&[
        "sl", "d", "h", "ts", "method", "dsp", "bram18k", "freq_mhz", "SA_ms", "LWA_ms", "FFN1_ms", "total_ms", "max_err_pct",
    ]);
    for (sl, d, h, tm, tf) in rows {
        let cfg = TnnConfig::encoder(sl, d, h, 12);
        let tiles = TileConfig::for_fabric(tm, tf, 768);
        let v = sweep::validate(&cfg, &tiles, &p, BW);
        t.row(vec![
            sl.to_string(),
            d.to_string(),
            h.to_string(),
            format!("{tm}/{tf}"),
            "analytical".into(),
            fmt_f(v.dsp_analytical, 0),
            fmt_f(v.bram_analytical, 0),
            fmt_f(v.freq_mhz, 0),
            fmt_f(v.sa_ms_analytical, 4),
            fmt_f(v.lwa_ms_analytical, 4),
            fmt_f(v.ffn_ms_analytical, 4),
            fmt_f(v.total_ms_analytical, 2),
            String::new(),
        ]);
        t.row(vec![
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            "simulated".into(),
            v.dsp_structural.to_string(),
            v.bram_structural.to_string(),
            fmt_f(v.freq_mhz, 0),
            fmt_f(v.sa_ms_simulated, 4),
            fmt_f(v.lwa_ms_simulated, 4),
            fmt_f(v.ffn_ms_simulated, 4),
            fmt_f(v.total_ms_simulated, 2),
            fmt_f(100.0 * v.max_latency_error(), 2),
        ]);
        // Third method: replay the *executed* TileProgram through the
        // cycle backend — the experimental column from the same source of
        // truth as the PJRT engine's request path.
        let fc = FabricConstants {
            dk: d / h,
            ts_mha: tm,
            ts_ffn: tf,
            ffn_col: 4 * tf,
            ..FabricConstants::artifact_default()
        };
        // The engine schedules FFN tiles over the *runtime* d (its panels
        // are fabric-wide but only d/TS of them run), so the replay's
        // error is taken against the closed form on that same geometry.
        let replay = cycle::estimate(&cfg, &fc, AttentionMode::Split, false, false);
        let (replay_ms, replay_err, replay_cycles) = match &replay {
            Ok(r) => {
                let ms = r.ms_at(v.freq_mhz);
                let ana_rt = latency::model_latency(&cfg, &fc.tile_config()).ms_at(v.freq_mhz);
                let err = (ms - ana_rt).abs() / ana_rt;
                (fmt_f(ms, 2), fmt_f(100.0 * err, 2), Some(r.total_cycles))
            }
            Err(e) => (format!("n/a ({e})"), String::new(), None),
        };
        t.row(vec![
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            "replayed".into(),
            String::new(),
            String::new(),
            fmt_f(v.freq_mhz, 0),
            String::new(),
            String::new(),
            String::new(),
            replay_ms,
            replay_err,
        ]);
        // Fourth method: wave-price the *optimized* program
        // (accel::schedule::opt) — each wave of independent dispatches
        // costs its slowest member, the PE-array-utilization analog.  The
        // last column reports the reduction vs the sequential replay.
        let (wave_ms, wave_cut) = match cycle::estimate_opt(
            &cfg,
            &fc,
            AttentionMode::Split,
            false,
            false,
            OptLevel::O1,
        ) {
            Ok(r) => {
                let cut = replay_cycles
                    .map(|seq| 100.0 * (1.0 - r.total_cycles as f64 / seq as f64))
                    .map(|c| fmt_f(c, 2))
                    .unwrap_or_default();
                (fmt_f(r.ms_at(v.freq_mhz), 2), cut)
            }
            Err(e) => (format!("n/a ({e})"), String::new()),
        };
        t.row(vec![
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            "replayed+waves".into(),
            String::new(),
            String::new(),
            fmt_f(v.freq_mhz, 0),
            String::new(),
            String::new(),
            String::new(),
            wave_ms,
            wave_cut,
        ]);
    }
    // ---- generation rows: decoder prefill vs per-token decode-step,
    // priced by replaying the engine's own prefill/step programs through
    // the cycle backend (KV-cached generation's cost split).
    for (dec_cfg, label) in [
        (presets::gpt_small(64, 4), "gpt-small"),
        (presets::transformer_base(64), "tf-base"),
    ] {
        let fc = FabricConstants::artifact_default();
        let v = sweep::validate(&dec_cfg, &fc.tile_config(), &p, BW);
        let rows = [
            ("prefill", cycle::estimate_prefill(&dec_cfg, &fc)),
            ("decode-step", cycle::estimate_step(&dec_cfg, &fc)),
        ];
        let prefill_cycles = rows[0].1.as_ref().ok().map(|r| r.total_cycles);
        for (method, rep) in rows {
            let (ms, extra) = match &rep {
                Ok(r) => {
                    // Last column: a step's cost as % of one prefill —
                    // the marginal-token saving the KV cache buys.
                    let pct = match (method, prefill_cycles) {
                        ("decode-step", Some(pre)) => {
                            fmt_f(100.0 * r.total_cycles as f64 / pre as f64, 2)
                        }
                        _ => String::new(),
                    };
                    (fmt_f(r.ms_at(v.freq_mhz), 4), pct)
                }
                Err(e) => (format!("n/a ({e})"), String::new()),
            };
            t.row(vec![
                dec_cfg.seq_len.to_string(),
                dec_cfg.d_model.to_string(),
                dec_cfg.heads.to_string(),
                label.to_string(),
                method.into(),
                String::new(),
                String::new(),
                fmt_f(v.freq_mhz, 0),
                String::new(),
                String::new(),
                String::new(),
                ms,
                extra,
            ]);
        }
    }
    let mut s = String::new();
    let _ = writeln!(s, "Table 2 — analytical model vs cycle-level simulation (paper: <=1.8% latency error)");
    let _ = writeln!(s, "('replayed' rows price the engine's own TileProgram through the cycle backend;");
    let _ = writeln!(s, " 'replayed+waves' wave-prices the optimized program — last column is % cycles cut;");
    let _ = writeln!(s, " 'prefill'/'decode-step' rows price the generation programs — the decode-step");
    let _ = writeln!(s, " column's last field is the per-token cost as % of one prefill)");
    s.push_str(&t.render());
    (s, t)
}

/// Extra: the adaptivity ablation (deployment cost vs a per-model
/// re-synthesized accelerator) — quantifies §1's motivation.
pub fn ablation_adaptivity() -> (String, TextTable) {
    let p = platform::u55c();
    let models = vec![
        presets::bert_base(64),
        presets::shallow_transformer(),
        presets::custom_encoder_4l(),
        presets::small_encoder(64, 4),
    ];
    let c = nonadaptive::deployment_cost(&models, &p, &TileConfig::paper_optimum(), BW);
    let mut t = TextTable::new(&["flow", "synthesis_hours", "sum_inference_ms"]);
    t.row(vec!["ADAPTOR (runtime registers)".into(), fmt_f(c.adaptor_synthesis_hours, 0), fmt_f(c.adaptor_inference_ms, 1)]);
    t.row(vec!["per-model custom synthesis".into(), fmt_f(c.nonadaptive_synthesis_hours, 0), fmt_f(c.nonadaptive_inference_ms, 1)]);
    let mut s = String::new();
    let _ = writeln!(s, "Ablation — runtime adaptivity vs per-model re-synthesis over {} models", c.models);
    s.push_str(&t.render());
    (s, t)
}

/// All report generators by name.
pub fn all() -> Vec<(&'static str, fn() -> (String, TextTable))> {
    vec![
        ("fig5", fig05 as fn() -> (String, TextTable)),
        ("fig8", fig08),
        ("fig9", fig09),
        ("fig10", fig10),
        ("fig11", fig11),
        ("fig12", fig12),
        ("fig13", fig13),
        ("table1", table1),
        ("table2", table2),
        ("ablation", ablation_adaptivity),
    ]
}

/// Render one report by name.
pub fn render(name: &str) -> Option<String> {
    all().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f().0)
}

/// Write every report (txt + csv) into `out_dir`.
pub fn write_all(out_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Vec<String>> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let mut written = Vec::new();
    for (name, f) in all() {
        let (text, table) = f();
        let txt = dir.join(format!("{name}.txt"));
        std::fs::write(&txt, &text)?;
        let csv = dir.join(format!("{name}.csv"));
        std::fs::write(&csv, table.to_csv())?;
        written.push(name.to_string());
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_report_renders_nonempty() {
        for (name, f) in all() {
            let (text, table) = f();
            assert!(text.len() > 100, "{name} too short");
            assert!(!table.rows.is_empty(), "{name} has no rows");
        }
    }

    #[test]
    fn table1_contains_substrate_and_paper_rows() {
        let (text, _) = table1();
        assert!(text.contains("ADAPTOR-RS (substrate)"));
        assert!(text.contains("FTRANS"));
        assert!(text.contains("FQ-BERT"));
    }

    #[test]
    fn table2_reports_small_errors() {
        let (_, t) = table2();
        // every "simulated" row carries a max_err_pct < 6
        for r in t.rows.iter().filter(|r| r[4] == "simulated") {
            let err: f64 = r[12].parse().unwrap();
            assert!(err < 6.0, "validation error {err}%");
        }
        // and every schedule-replay row lands in the same band
        let replayed: Vec<_> = t.rows.iter().filter(|r| r[4] == "replayed").collect();
        assert_eq!(replayed.len(), 4, "one replay row per Table 2 config");
        for r in &replayed {
            assert!(
                !r[11].starts_with("n/a"),
                "every Table 2 topology must lower to a program: {}",
                r[11]
            );
            let err: f64 = r[12].parse().unwrap();
            assert!(err < 6.0, "schedule-replay error {err}%");
        }
        // wave pricing must strictly beat the sequential replay on every
        // Table 2 topology (all are multi-head) — the utilization claim
        let waved: Vec<_> = t.rows.iter().filter(|r| r[4] == "replayed+waves").collect();
        assert_eq!(waved.len(), 4, "one wave row per Table 2 config");
        for (seq, wav) in replayed.iter().zip(&waved) {
            assert!(
                !wav[11].starts_with("n/a"),
                "every Table 2 topology must wave-schedule: {}",
                wav[11]
            );
            let seq_ms: f64 = seq[11].parse().unwrap();
            let wav_ms: f64 = wav[11].parse().unwrap();
            assert!(
                wav_ms < seq_ms,
                "wave-priced replay ({wav_ms} ms) must beat sequential ({seq_ms} ms)"
            );
            let cut: f64 = wav[12].parse().unwrap();
            assert!(cut > 0.0, "cycles-cut column must be positive, got {cut}");
        }
        // generation rows: every decoder workload gets a prefill and a
        // decode-step price, and the cached step is far below the prefill
        let prefill: Vec<_> = t.rows.iter().filter(|r| r[4] == "prefill").collect();
        let steps: Vec<_> = t.rows.iter().filter(|r| r[4] == "decode-step").collect();
        assert_eq!(prefill.len(), 2, "one prefill row per decoder workload");
        assert_eq!(steps.len(), 2);
        for (pre, step) in prefill.iter().zip(&steps) {
            let pre_ms: f64 = pre[11].parse().unwrap();
            let step_ms: f64 = step[11].parse().unwrap();
            assert!(
                step_ms < pre_ms / 4.0,
                "per-token step ({step_ms} ms) must be far below prefill ({pre_ms} ms)"
            );
            let pct: f64 = step[12].parse().unwrap();
            assert!(pct > 0.0 && pct < 25.0, "step-vs-prefill % out of band: {pct}");
        }
    }

    #[test]
    fn fig11_reports_all_three_platforms() {
        let (text, t) = fig11();
        assert_eq!(t.rows.len(), 3);
        for name in ["Alveo U55C", "ZCU102", "VC707"] {
            assert!(text.contains(name), "{name} missing");
        }
    }

    #[test]
    fn render_by_name() {
        assert!(render("fig5").is_some());
        assert!(render("nope").is_none());
    }
}
