//! Design-space sweeps behind Figs 5, 8, 9 and 13.

use crate::accel::platform::Platform;
use crate::accel::{frequency, latency, resources, sim, tiling::TileConfig};
use crate::model::quant::BitWidth;
use crate::model::TnnConfig;

/// One design point in a tile/head sweep.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub tiles_mha: usize,
    pub tiles_ffn: usize,
    pub ts_mha: usize,
    pub ts_ffn: usize,
    pub heads: usize,
    pub dsp: u64,
    pub dsp_util: f64,
    pub lut: u64,
    pub lut_util: f64,
    pub bram18k: u64,
    pub bram_util: f64,
    pub freq_mhz: f64,
    pub latency_ms: f64,
    pub gops: f64,
    pub fits: bool,
}

fn point(cfg: &TnnConfig, tiles: TileConfig, platform: &Platform, bw: BitWidth) -> DesignPoint {
    let r = resources::estimate(cfg, &tiles, bw, platform);
    let f = frequency::fmax_mhz(platform, &r);
    let lat = latency::model_latency(cfg, &tiles);
    DesignPoint {
        tiles_mha: tiles.tiles_mha(cfg.d_model),
        tiles_ffn: tiles.tiles_ffn(cfg.d_model),
        ts_mha: tiles.ts_mha,
        ts_ffn: tiles.ts_ffn,
        heads: cfg.heads,
        dsp: r.dsp,
        dsp_util: r.dsp_util,
        lut: r.lut,
        lut_util: r.lut_util,
        bram18k: r.bram18k,
        bram_util: r.bram_util,
        freq_mhz: f,
        latency_ms: lat.ms_at(f),
        gops: lat.gops_at(cfg, f),
        fits: r.check_fit(platform).is_ok(),
    }
}

/// Fig 5's sweep: MHA tile count 6–48 for each FFN tile count 2–6
/// (divisors of d_model only, as in the paper's d_model = 768 grid).
pub fn tile_sweep(cfg: &TnnConfig, platform: &Platform, bw: BitWidth) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    for t_ffn in 2..=6usize {
        if cfg.d_model % t_ffn != 0 {
            continue;
        }
        for t_mha in [6usize, 8, 12, 16, 24, 32, 48] {
            if cfg.d_model % t_mha != 0 {
                continue;
            }
            let tiles = TileConfig::new(cfg.d_model / t_mha, cfg.d_model / t_ffn);
            out.push(point(cfg, tiles, platform, bw));
        }
    }
    out
}

/// Fig 8's sweep: head count 2–16 on the fixed default fabric.
pub fn heads_sweep(base: &TnnConfig, platform: &Platform, bw: BitWidth) -> Vec<DesignPoint> {
    let tiles = TileConfig::paper_optimum();
    (1..=8usize)
        .map(|i| 2 * i)
        .filter(|h| base.d_model % h == 0)
        .map(|h| {
            let cfg = TnnConfig { heads: h, ..*base };
            point(&cfg, tiles, platform, bw)
        })
        .collect()
}

/// The best point of a sweep by latency (the paper's §3.10 selection).
pub fn best_by_latency(points: &[DesignPoint]) -> Option<&DesignPoint> {
    points
        .iter()
        .filter(|p| p.fits)
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
}

/// Analytical-vs-simulated validation record (Table 2 rows).
#[derive(Debug, Clone)]
pub struct ValidationRow {
    pub seq_len: usize,
    pub d_model: usize,
    pub heads: usize,
    pub ts_mha: usize,
    pub ts_ffn: usize,
    pub dsp_analytical: f64,
    pub dsp_structural: u64,
    pub bram_analytical: f64,
    pub bram_structural: u64,
    pub freq_mhz: f64,
    pub sa_ms_analytical: f64,
    pub sa_ms_simulated: f64,
    pub lwa_ms_analytical: f64,
    pub lwa_ms_simulated: f64,
    pub ffn_ms_analytical: f64,
    pub ffn_ms_simulated: f64,
    pub total_ms_analytical: f64,
    pub total_ms_simulated: f64,
}

impl ValidationRow {
    pub fn max_latency_error(&self) -> f64 {
        [
            (self.sa_ms_analytical, self.sa_ms_simulated),
            (self.lwa_ms_analytical, self.lwa_ms_simulated),
            (self.ffn_ms_analytical, self.ffn_ms_simulated),
            (self.total_ms_analytical, self.total_ms_simulated),
        ]
        .iter()
        .map(|(a, s)| (a - s).abs() / a.max(1e-12))
        .fold(0.0, f64::max)
    }
}

/// Run one Table 2 validation row.
///
/// Resources and frequency belong to the *synthesis* (the fabric is fixed;
/// Table 2 rows 1–3 share 3612 DSPs / 2246 BRAMs across runtime SL and
/// d_model changes); only the latency columns vary with the runtime
/// registers.  The synthesis workload is the paper's default build.
pub fn validate(cfg: &TnnConfig, tiles: &TileConfig, platform: &Platform, bw: BitWidth) -> ValidationRow {
    let synth_cfg = TnnConfig::encoder(64, 768, 8, 12);
    let r = resources::estimate(&synth_cfg, tiles, bw, platform);
    let f = frequency::fmax_mhz(platform, &r);
    let ana = latency::model_latency(cfg, tiles);
    let s = sim::simulate(cfg, tiles);
    let ms = |cc: u64| cc as f64 / (f * 1e3);
    ValidationRow {
        seq_len: cfg.seq_len,
        d_model: cfg.d_model,
        heads: cfg.heads,
        ts_mha: tiles.ts_mha,
        ts_ffn: tiles.ts_ffn,
        dsp_analytical: r.dsp_analytical,
        dsp_structural: r.dsp,
        bram_analytical: r.bram18k_analytical,
        bram_structural: r.bram18k,
        freq_mhz: f,
        sa_ms_analytical: ms(latency::attention::qkv_tile(cfg, tiles)),
        sa_ms_simulated: ms(s.layer.sa_visit),
        lwa_ms_analytical: ms(latency::attention::load_weights_head_tile(cfg, tiles)),
        lwa_ms_simulated: ms(s.layer.lwa_visit),
        ffn_ms_analytical: ms(latency::ffn::ffn1_visit(cfg, tiles)),
        ffn_ms_simulated: ms(s.layer.ffn_visit),
        total_ms_analytical: ana.ms_at(f),
        total_ms_simulated: s.ms_at(f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform;
    use crate::model::presets;

    #[test]
    fn fig5_optimum_is_mid_grid() {
        // paper: "the optimal configuration ... was 12 tiles in MHA and 6
        // tiles in FFN" — the sweep's latency optimum must be an interior
        // point (neither the fewest-DSP nor the most-DSP corner).
        let cfg = TnnConfig::encoder(64, 768, 8, 12);
        let pts = tile_sweep(&cfg, &platform::u55c(), BitWidth::Fixed16);
        let best = best_by_latency(&pts).unwrap();
        assert!(best.tiles_mha >= 6 && best.tiles_mha <= 24, "{:?}", best);
        assert!(best.tiles_ffn >= 3, "{:?}", best);
        assert_eq!(best.freq_mhz, 200.0, "optimum must hold target clock");
    }

    #[test]
    fn heads_sweep_resources_grow() {
        let base = TnnConfig::encoder(64, 768, 8, 12);
        let pts = heads_sweep(&base, &platform::u55c(), BitWidth::Fixed16);
        assert!(pts.len() >= 4);
        assert!(pts.last().unwrap().dsp > pts.first().unwrap().dsp);
        // frequency is non-increasing with head count (Fig 8a mechanism)
        for w in pts.windows(2) {
            assert!(w[1].freq_mhz <= w[0].freq_mhz + 1e-9);
        }
    }

    #[test]
    fn validation_rows_meet_paper_error_band() {
        // Table 2: experimental latency within ~1.8% of analytical; we
        // grant our two implementations 3%.
        let p = platform::u55c();
        for (sl, d) in [(64usize, 768usize), (128, 768), (64, 512)] {
            let cfg = TnnConfig::encoder(sl, d, 8, 12);
            let row = validate(&cfg, &TileConfig::paper_optimum(), &p, BitWidth::Fixed16);
            assert!(row.max_latency_error() < 0.03, "err = {}", row.max_latency_error());
        }
    }

    #[test]
    fn sweep_points_are_unique_designs() {
        let cfg = presets::paper_default();
        let pts = tile_sweep(&cfg, &platform::u55c(), BitWidth::Fixed16);
        let mut keys: Vec<_> = pts.iter().map(|p| (p.ts_mha, p.ts_ffn)).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), pts.len());
    }
}
