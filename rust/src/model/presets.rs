//! The workloads the paper evaluates (§6, Table 1, Figs 10–12).
//!
//! Where the paper under-specifies a network (e.g. "shallow transformer"
//! from Fang et al. [44], Qi et al. [19][33]) we pin the commonly cited
//! configuration and document the choice; the comparison figures depend on
//! the op counts' *shape*, which these choices preserve.

use super::TnnConfig;

/// BERT-base (Devlin et al. [10]); the paper's default register values:
/// d_model = 768, h = 12, N = 12, SL = 64 (§6).
pub fn bert_base(seq_len: usize) -> TnnConfig {
    TnnConfig::encoder(seq_len, 768, 12, 12)
}

/// The paper's default configuration exactly as synthesized (§6).
pub fn paper_default() -> TnnConfig {
    bert_base(64)
}

/// "Shallow transformer" (Network #1 in Table 1, after Fang et al. [44] /
/// Qi et al. [19]): a 2-layer, d_model = 512, 8-head encoder at SL = 64.
pub fn shallow_transformer() -> TnnConfig {
    TnnConfig::encoder(64, 512, 8, 2)
}

/// The Fig-11 portability workload: "custom TNN encoder with an embedding
/// dimension of 200, 3 attention heads, 2 encoder layers, and a sequence
/// length of 64".  (Note 200 % 3 != 0 — executable only by the analytical
/// and simulation paths, exactly as in the paper where the fabric rounds
/// the head dimension.)
pub fn custom_encoder() -> TnnConfig {
    TnnConfig::encoder(64, 200, 3, 2)
}

/// Custom encoder variant used by Table 1 Network #2 (Qi et al. [33]
/// four-layer transformer encoder).
pub fn custom_encoder_4l() -> TnnConfig {
    TnnConfig::encoder(64, 512, 8, 4)
}

/// Transformer base (Vaswani et al. [8]): 6 encoder + 6 decoder layers,
/// d_model = 512, h = 8, d_k = 64.
pub fn transformer_base(seq_len: usize) -> TnnConfig {
    TnnConfig { seq_len, heads: 8, d_model: 512, hidden: 2048, enc_layers: 6, dec_layers: 6 }
}

/// Transformer big (Vaswani et al. [8]): h = 16, d_model = 1024.
pub fn transformer_big(seq_len: usize) -> TnnConfig {
    TnnConfig { seq_len, heads: 16, d_model: 1024, hidden: 4096, enc_layers: 6, dec_layers: 6 }
}

/// A small executable encoder matching the `small_layer` fused artifact
/// (d = 256, h = 4) — the e2e serving example's model.
pub fn small_encoder(seq_len: usize, layers: usize) -> TnnConfig {
    TnnConfig::encoder(seq_len, 256, 4, layers)
}

/// A GPT-style **decoder-only** topology (d = 256, h = 4, no encoder
/// stack): causal self-attention + FFN per layer, served through the
/// prefill/decode-step generation path.  Executable on the default fabric
/// (dk = 64, hidden = 4d).
pub fn gpt_small(seq_len: usize, layers: usize) -> TnnConfig {
    TnnConfig { seq_len, heads: 4, d_model: 256, hidden: 1024, enc_layers: 0, dec_layers: layers }
}

/// A small executable **seq2seq** topology (encoder + cross-attending
/// decoder, d = 256, h = 4) — the generation regression workload.
pub fn seq2seq_small(seq_len: usize, enc_layers: usize, dec_layers: usize) -> TnnConfig {
    TnnConfig { seq_len, heads: 4, d_model: 256, hidden: 1024, enc_layers, dec_layers }
}

/// All named presets, for CLI listing.
pub fn all() -> Vec<(&'static str, TnnConfig)> {
    vec![
        ("bert-base", bert_base(64)),
        ("paper-default", paper_default()),
        ("shallow", shallow_transformer()),
        ("custom-encoder", custom_encoder()),
        ("custom-encoder-4l", custom_encoder_4l()),
        ("transformer-base", transformer_base(64)),
        ("transformer-big", transformer_big(64)),
        ("small", small_encoder(64, 4)),
        ("gpt-small", gpt_small(64, 4)),
        ("seq2seq-small", seq2seq_small(64, 2, 2)),
    ]
}

/// Look a preset up by CLI name.
pub fn by_name(name: &str) -> Option<TnnConfig> {
    all().into_iter().find(|(n, _)| *n == name).map(|(_, c)| c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for (name, c) in all() {
            assert!(c.validate().is_ok(), "{name}");
        }
    }

    #[test]
    fn bert_base_matches_paper_registers() {
        let c = paper_default();
        assert_eq!((c.d_model, c.heads, c.enc_layers, c.seq_len), (768, 12, 12, 64));
        assert_eq!(c.dk(), 64); // d_k = 64 in base and large (§2.1)
    }

    #[test]
    fn transformer_base_and_big_match_vaswani() {
        let b = transformer_base(64);
        assert_eq!((b.d_model, b.heads, b.dk()), (512, 8, 64));
        let g = transformer_big(64);
        assert_eq!((g.d_model, g.heads, g.dk()), (1024, 16, 64));
    }

    #[test]
    fn generation_presets_are_executable_shapes() {
        let g = gpt_small(64, 4);
        assert_eq!((g.enc_layers, g.dec_layers, g.dk()), (0, 4, 64));
        assert!(g.validate_for_execution().is_ok());
        let s = seq2seq_small(64, 2, 2);
        assert_eq!((s.enc_layers, s.dec_layers, s.hidden), (2, 2, 4 * s.d_model));
        assert!(s.validate_for_execution().is_ok());
    }

    #[test]
    fn by_name_roundtrip() {
        for (name, c) in all() {
            assert_eq!(by_name(name), Some(c));
        }
        assert_eq!(by_name("nope"), None);
    }
}
