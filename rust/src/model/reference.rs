//! Dense f32 CPU reference implementation of the transformer encoder —
//! the rust-side oracle for the PJRT tile engine (and the "CPU baseline"
//! executor for speedup shapes).
//!
//! Mirrors `python/compile/kernels/ref.py` / `model.ref_encoder_layer`
//! operation-for-operation (post-LN residuals, 1/sqrt(d_k) scaling,
//! eps = 1e-5) so all three implementations — jnp oracle, Pallas kernels,
//! and the rust tile engine over AOT artifacts — agree to f32 tolerance.

use super::weights::{LayerWeights, Mat};

pub const LN_EPS: f32 = 1e-5;
pub const NEG_INF: f32 = -1e9;

/// `a @ b` (naive triple loop — this is the oracle, clarity over speed).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a.at(i, k);
            if av == 0.0 {
                continue;
            }
            for j in 0..b.cols {
                *out.at_mut(i, j) += av * b.at(k, j);
            }
        }
    }
    out
}

/// `a @ b^T`.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_nt shape mismatch");
    let mut out = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        for j in 0..b.rows {
            let mut s = 0.0;
            for k in 0..a.cols {
                s += a.at(i, k) * b.at(j, k);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

pub fn add_bias(x: &mut Mat, b: &[f32]) {
    assert_eq!(x.cols, b.len());
    for r in 0..x.rows {
        for c in 0..x.cols {
            *x.at_mut(r, c) += b[c];
        }
    }
}

pub fn relu(x: &mut Mat) {
    for v in &mut x.data {
        *v = v.max(0.0);
    }
}

/// Numerically-stable row softmax (Algorithm 7: max, exp, normalize).
pub fn softmax_rows(x: &mut Mat) {
    for r in 0..x.rows {
        let row = &mut x.data[r * x.cols..(r + 1) * x.cols];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Additive attention mask: 0 on legal (i,j), NEG_INF otherwise.
/// `valid` limits both query and key positions; `causal` restricts j <= i.
pub fn attention_mask(sl: usize, valid: usize, causal: bool) -> Mat {
    Mat::from_fn(sl, sl, |i, j| {
        let legal = i < valid && j < valid && (!causal || j <= i);
        if legal {
            0.0
        } else {
            NEG_INF
        }
    })
}

/// LayerNorm(x + res) row-wise with affine (Eq 4), full width.
pub fn residual_ln(x: &Mat, res: &Mat, gamma: &[f32], beta: &[f32]) -> Mat {
    assert_eq!((x.rows, x.cols), (res.rows, res.cols));
    let d = x.cols as f32;
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let mut mu = 0.0;
        for c in 0..x.cols {
            mu += x.at(r, c) + res.at(r, c);
        }
        mu /= d;
        let mut var = 0.0;
        for c in 0..x.cols {
            let z = x.at(r, c) + res.at(r, c) - mu;
            var += z * z;
        }
        var /= d;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for c in 0..x.cols {
            let z = x.at(r, c) + res.at(r, c) - mu;
            *out.at_mut(r, c) = gamma[c] * z * inv + beta[c];
        }
    }
    out
}

/// One attention head: softmax(mask(scale·Q·Kᵀ))·V — Eq 1.
pub fn attention_head(q: &Mat, k: &Mat, v: &Mat, mask: &Mat, scale: f32) -> Mat {
    let mut s = matmul_nt(q, k);
    for (sv, mv) in s.data.iter_mut().zip(&mask.data) {
        *sv = *sv * scale + mv;
    }
    softmax_rows(&mut s);
    matmul(&s, v)
}

/// One full encoder layer (Eq 1-4) — the oracle for the tile engine.
pub fn encoder_layer(x: &Mat, w: &LayerWeights, mask: &Mat) -> Mat {
    let heads = w.wq.len();
    let dk = w.wq[0].cols;
    let scale = 1.0 / (dk as f32).sqrt();
    let d_model = x.cols;

    // MHA, head by head, concatenated.
    let mut attn = Mat::zeros(x.rows, d_model);
    for h in 0..heads {
        let mut q = matmul(x, &w.wq[h]);
        add_bias(&mut q, &w.bq[h]);
        let mut k = matmul(x, &w.wk[h]);
        add_bias(&mut k, &w.bk[h]);
        let mut v = matmul(x, &w.wv[h]);
        add_bias(&mut v, &w.bv[h]);
        let o = attention_head(&q, &k, &v, mask, scale);
        attn.set_block(0, h * dk, &o);
    }

    // FFN1_PM: output projection + residual + LN.
    let mut proj = matmul(&attn, &w.wo);
    add_bias(&mut proj, &w.bo);
    let y = residual_ln(&proj, x, &w.g1, &w.b1n);

    // FFN2_PM (ReLU) -> FFN3_PM + residual + LN.
    let mut hidden = matmul(&y, &w.w1);
    add_bias(&mut hidden, &w.b1);
    relu(&mut hidden);
    let mut out = matmul(&hidden, &w.w2);
    add_bias(&mut out, &w.b2);
    residual_ln(&out, &y, &w.g2, &w.b2n)
}

/// N-layer encoder stack.
pub fn encoder_stack(x: &Mat, layers: &[LayerWeights], mask: &Mat) -> Mat {
    let mut cur = x.clone();
    for w in layers {
        cur = encoder_layer(&cur, w, mask);
    }
    cur
}

// ---- decoder oracle ------------------------------------------------------

use super::weights::DecoderLayerWeights;

/// Multi-head attention with separate query (`xq`) and key/value (`xkv`)
/// streams — self-attention when they coincide, cross-attention when
/// `xkv` is the encoder memory.  `mask` is `xq.rows x xkv.rows` additive.
#[allow(clippy::too_many_arguments)]
fn mha(
    xq: &Mat,
    xkv: &Mat,
    wq: &[Mat],
    wk: &[Mat],
    wv: &[Mat],
    bq: &[Vec<f32>],
    bk: &[Vec<f32>],
    bv: &[Vec<f32>],
    mask: &Mat,
) -> Mat {
    let heads = wq.len();
    let dk = wq[0].cols;
    let scale = 1.0 / (dk as f32).sqrt();
    let mut out = Mat::zeros(xq.rows, heads * dk);
    for h in 0..heads {
        let mut q = matmul(xq, &wq[h]);
        add_bias(&mut q, &bq[h]);
        let mut k = matmul(xkv, &wk[h]);
        add_bias(&mut k, &bk[h]);
        let mut v = matmul(xkv, &wv[h]);
        add_bias(&mut v, &bv[h]);
        let o = attention_head(&q, &k, &v, mask, scale);
        out.set_block(0, h * dk, &o);
    }
    out
}

/// One decoder layer (Vaswani §3.1, post-LN): masked self-attention →
/// add&norm, then (iff the layer has a cross block AND a memory is given)
/// cross-attention against `mem` → add&norm, then the FFN → add&norm.
/// `self_mask` is causal over the decoder stream; `cross_mask` is
/// `x.rows x mem.rows` additive (all-zero when both sides are exact).
pub fn decoder_layer(
    x: &Mat,
    mem: Option<&Mat>,
    w: &DecoderLayerWeights,
    self_mask: &Mat,
    cross_mask: Option<&Mat>,
) -> Mat {
    let b = &w.base;
    // Masked self-attention block (causality lives in self_mask).
    let attn = mha(x, x, &b.wq, &b.wk, &b.wv, &b.bq, &b.bk, &b.bv, self_mask);
    let mut proj = matmul(&attn, &b.wo);
    add_bias(&mut proj, &b.bo);
    let y1 = residual_ln(&proj, x, &b.g1, &b.b1n);

    // Cross-attention block.
    let y2 = match (&w.cross, mem) {
        (Some(c), Some(m)) => {
            let zeros;
            let cmask = match cross_mask {
                Some(cm) => cm,
                None => {
                    zeros = Mat::zeros(y1.rows, m.rows);
                    &zeros
                }
            };
            let cat = mha(&y1, m, &c.wq, &c.wk, &c.wv, &c.bq, &c.bk, &c.bv, cmask);
            let mut cp = matmul(&cat, &c.wo);
            add_bias(&mut cp, &c.bo);
            residual_ln(&cp, &y1, &c.g, &c.bn)
        }
        (None, _) => y1,
        (Some(_), None) => panic!("seq2seq decoder layer needs an encoder memory"),
    };

    // FFN block.
    let mut hidden = matmul(&y2, &b.w1);
    add_bias(&mut hidden, &b.b1);
    relu(&mut hidden);
    let mut out = matmul(&hidden, &b.w2);
    add_bias(&mut out, &b.b2);
    residual_ln(&out, &y2, &b.g2, &b.b2n)
}

/// N-layer decoder stack (one shared memory for every layer's cross
/// block, as in the original transformer).
pub fn decoder_stack(
    x: &Mat,
    mem: Option<&Mat>,
    layers: &[DecoderLayerWeights],
    self_mask: &Mat,
    cross_mask: Option<&Mat>,
) -> Mat {
    let mut cur = x.clone();
    for w in layers {
        cur = decoder_layer(&cur, mem, w, self_mask, cross_mask);
    }
    cur
}

/// The "token" a continuous activation row greedily decodes to: the
/// argmax feature index (the substrate's pseudo-vocabulary is the
/// embedding basis — the accelerator is weight- and vocab-agnostic, so
/// generation feeds the continuous row back and reports the argmax id).
pub fn argmax_token(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .fold((0usize, f32::NEG_INFINITY), |(bi, bv), (i, &v)| {
            if v > bv {
                (i, v)
            } else {
                (bi, bv)
            }
        })
        .0
}

/// A greedy autoregressive decode's outputs.
#[derive(Debug, Clone)]
pub struct GreedyDecode {
    /// The generated activation rows, `steps x d_model`.
    pub rows: Mat,
    /// Per-step greedy token ids ([`argmax_token`] of each row).
    pub tokens: Vec<usize>,
}

/// Greedy autoregressive decoding oracle: starting from `prompt`
/// (`m x d_model` rows), repeatedly run the full decoder stack with a
/// causal mask over the current sequence, take the last row as the next
/// "token" (continuous feed-back, argmax reported as the token id), and
/// append it.  This is what `TileEngine::generate` (prefill + KV-cached
/// steps) must reproduce — causality makes the incremental and the
/// recompute-everything formulations identical.
pub fn greedy_decode(
    prompt: &Mat,
    mem: Option<&Mat>,
    layers: &[DecoderLayerWeights],
    steps: usize,
) -> GreedyDecode {
    assert!(prompt.rows > 0, "greedy decode needs at least one prompt row");
    let d = prompt.cols;
    let mut x = prompt.clone();
    let mut rows = Mat::zeros(steps, d);
    let mut tokens = Vec::with_capacity(steps);
    for s in 0..steps {
        let n = x.rows;
        let self_mask = attention_mask(n, n, true);
        let y = decoder_stack(&x, mem, layers, &self_mask, None);
        let next: Vec<f32> = (0..d).map(|c| y.at(n - 1, c)).collect();
        tokens.push(argmax_token(&next));
        for (c, v) in next.iter().enumerate() {
            *rows.at_mut(s, c) = *v;
        }
        let mut grown = Mat::zeros(n + 1, d);
        grown.set_block(0, 0, &x);
        for (c, v) in next.iter().enumerate() {
            *grown.at_mut(n, c) = *v;
        }
        x = grown;
    }
    GreedyDecode { rows, tokens }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |r, c| (r * 3 + c) as f32);
        let id = Mat::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(matmul(&a, &id), a);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = Mat::from_fn(2, 4, |r, c| (r + c) as f32);
        let b = Mat::from_fn(3, 4, |r, c| (r * c) as f32 + 1.0);
        let bt = Mat::from_fn(4, 3, |r, c| b.at(c, r));
        assert_eq!(matmul_nt(&a, &b), matmul(&a, &bt));
    }

    #[test]
    fn softmax_rows_sum_to_one_and_stable() {
        let mut m = Mat::from_fn(4, 8, |r, c| (r * c) as f32 * 100.0);
        softmax_rows(&mut m);
        for r in 0..4 {
            let s: f32 = (0..8).map(|c| m.at(r, c)).sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.data.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn residual_ln_zero_mean_unit_var() {
        let x = weights::init_input(1, 16, 64);
        let r = weights::init_input(2, 16, 64);
        let out = residual_ln(&x, &r, &vec![1.0; 64], &vec![0.0; 64]);
        for row in 0..16 {
            let vals: Vec<f32> = (0..64).map(|c| out.at(row, c)).collect();
            let mu: f32 = vals.iter().sum::<f32>() / 64.0;
            let var: f32 = vals.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 64.0;
            assert!(mu.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn causal_mask_blocks_future() {
        let m = attention_mask(4, 4, true);
        assert_eq!(m.at(0, 1), NEG_INF);
        assert_eq!(m.at(3, 3), 0.0);
        assert_eq!(m.at(2, 1), 0.0);
        let p = attention_mask(4, 2, false);
        assert_eq!(p.at(0, 3), NEG_INF);
        assert_eq!(p.at(3, 0), NEG_INF);
    }

    #[test]
    fn attention_uniform_when_keys_equal() {
        // identical keys => uniform attention => output = mean of V rows
        let q = weights::init_input(3, 4, 8);
        let k = Mat::from_fn(4, 8, |_, c| c as f32 / 8.0);
        let v = Mat::from_fn(4, 8, |r, _| r as f32);
        let mask = attention_mask(4, 4, false);
        let o = attention_head(&q, &k, &v, &mask, 0.125);
        for r in 0..4 {
            assert!((o.at(r, 0) - 1.5).abs() < 1e-5, "{}", o.at(r, 0));
        }
    }

    #[test]
    fn encoder_layer_output_is_normalized() {
        let w = weights::init_layer(0, 128, 2);
        let x = weights::init_input(0, 16, 128);
        let mask = attention_mask(16, 16, false);
        let y = encoder_layer(&x, &w, &mask);
        for r in 0..16 {
            let row: Vec<f32> = (0..128).map(|c| y.at(r, c)).collect();
            let mu: f32 = row.iter().sum::<f32>() / 128.0;
            assert!(mu.abs() < 1e-4);
        }
    }

    #[test]
    fn decoder_layer_respects_causality() {
        // Changing a future row must not change earlier outputs.
        let w = weights::init_decoder_layer(5, 128, 2, false);
        let mut x = weights::init_input(7, 8, 128);
        let mask = attention_mask(8, 8, true);
        let a = decoder_layer(&x, None, &w, &mask, None);
        for c in 0..128 {
            *x.at_mut(7, c) += 3.0; // perturb only the last row
        }
        let b = decoder_layer(&x, None, &w, &mask, None);
        for r in 0..7 {
            for c in 0..128 {
                assert_eq!(a.at(r, c), b.at(r, c), "row {r} saw the future");
            }
        }
        assert!((0..128).any(|c| a.at(7, c) != b.at(7, c)));
    }

    #[test]
    fn cross_attention_reads_the_memory() {
        let w = weights::init_decoder_layer(6, 128, 2, true);
        let x = weights::init_input(8, 8, 128);
        let mask = attention_mask(8, 8, true);
        let mem_a = weights::init_input(9, 8, 128);
        let mem_b = weights::init_input(10, 8, 128);
        let a = decoder_layer(&x, Some(&mem_a), &w, &mask, None);
        let b = decoder_layer(&x, Some(&mem_b), &w, &mask, None);
        assert!(a.max_abs_diff(&b) > 1e-4, "memory must influence the output");
        // A decoder-only layer ignores any provided memory.
        let solo = weights::init_decoder_layer(6, 128, 2, false);
        let sa = decoder_layer(&x, Some(&mem_a), &solo, &mask, None);
        let sb = decoder_layer(&x, Some(&mem_b), &solo, &mask, None);
        assert_eq!(sa.max_abs_diff(&sb), 0.0);
    }

    #[test]
    fn greedy_decode_is_incremental_consistent() {
        // The oracle's defining property: generating k+1 tokens extends
        // the k-token generation (causality — earlier steps never change).
        let layers = weights::init_decoder_stack(11, 128, 2, 2, false);
        let prompt = weights::init_input(12, 4, 128);
        let short = greedy_decode(&prompt, None, &layers, 2);
        let long = greedy_decode(&prompt, None, &layers, 4);
        assert_eq!(short.tokens, long.tokens[..2]);
        for r in 0..2 {
            for c in 0..128 {
                assert_eq!(short.rows.at(r, c), long.rows.at(r, c));
            }
        }
        assert_eq!(long.tokens.len(), 4);
        assert!(long.rows.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn argmax_token_picks_the_peak() {
        assert_eq!(argmax_token(&[0.1, 3.0, -2.0]), 1);
        assert_eq!(argmax_token(&[5.0, 3.0]), 0);
    }

    #[test]
    fn stack_differs_from_single_layer() {
        let ws = weights::init_stack(0, 128, 2, 2);
        let x = weights::init_input(0, 8, 128);
        let mask = attention_mask(8, 8, false);
        let one = encoder_layer(&x, &ws[0], &mask);
        let two = encoder_stack(&x, &ws, &mask);
        assert!(one.max_abs_diff(&two) > 1e-3);
    }
}
