//! Row-major f32 matrices and deterministic synthetic weight generation.
//!
//! The paper extracts topologies from HuggingFace `.pth` checkpoints; the
//! accelerator itself is weight-agnostic (only shapes steer the fabric), so
//! this substrate generates reproducible pseudo-random weights (splitmix64,
//! fixed seed) with the same init scaling as `python/compile/model.py`.

use crate::util::rng::SplitMix64;
/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    /// Copy of the `rows x cols` sub-block at (r0, c0).
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Mat {
        assert!(r0 + rows <= self.rows && c0 + cols <= self.cols);
        Mat::from_fn(rows, cols, |r, c| self.at(r0 + r, c0 + c))
    }

    /// Write `src` into the sub-block at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Mat) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for r in 0..src.rows {
            for c in 0..src.cols {
                *self.at_mut(r0 + r, c0 + c) = src.at(r, c);
            }
        }
    }

    /// Zero-pad (or truncate is forbidden) to a larger shape.
    pub fn padded(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.rows && cols >= self.cols, "padded() cannot shrink");
        let mut out = Mat::zeros(rows, cols);
        out.set_block(0, 0, self);
        out
    }

    /// Max |a - b| over all elements (shape-checked).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

/// One encoder layer's parameters — field-for-field the Python
/// `LayerParams` (and therefore the fused artifacts' input order).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// Per-head projection panels, each `d_model x dk`.
    pub wq: Vec<Mat>,
    pub wk: Vec<Mat>,
    pub wv: Vec<Mat>,
    /// Per-head biases, each length `dk`.
    pub bq: Vec<Vec<f32>>,
    pub bk: Vec<Vec<f32>>,
    pub bv: Vec<Vec<f32>>,
    /// Attention output projection (FFN1_PM): `d_model x d_model`.
    pub wo: Mat,
    pub bo: Vec<f32>,
    /// FFN2_PM: `d_model x hidden`.
    pub w1: Mat,
    pub b1: Vec<f32>,
    /// FFN3_PM: `hidden x d_model`.
    pub w2: Mat,
    pub b2: Vec<f32>,
    /// LayerNorm affine parameters.
    pub g1: Vec<f32>,
    pub b1n: Vec<f32>,
    pub g2: Vec<f32>,
    pub b2n: Vec<f32>,
}

fn randn_mat(rng: &mut SplitMix64, rows: usize, cols: usize, scale: f32) -> Mat {
    Mat::from_fn(rows, cols, |_, _| rng.normal() as f32 * scale)
}

/// Deterministic weights for one encoder layer.
pub fn init_layer(seed: u64, d_model: usize, heads: usize) -> LayerWeights {
    assert_eq!(d_model % heads, 0, "execution weights need divisibility");
    let dk = d_model / heads;
    let hidden = 4 * d_model;
    let mut rng = SplitMix64::new(seed);
    let s_attn = 1.0 / (d_model as f32).sqrt();
    let s_ffn2 = 1.0 / (hidden as f32).sqrt();
    let heads_mat =
        |rng: &mut SplitMix64| (0..heads).map(|_| randn_mat(rng, d_model, dk, s_attn)).collect();
    LayerWeights {
        wq: heads_mat(&mut rng),
        wk: heads_mat(&mut rng),
        wv: heads_mat(&mut rng),
        bq: vec![vec![0.0; dk]; heads],
        bk: vec![vec![0.0; dk]; heads],
        bv: vec![vec![0.0; dk]; heads],
        wo: randn_mat(&mut rng, d_model, d_model, s_attn),
        bo: vec![0.0; d_model],
        w1: randn_mat(&mut rng, d_model, hidden, s_attn),
        b1: vec![0.0; hidden],
        w2: randn_mat(&mut rng, hidden, d_model, s_ffn2),
        b2: vec![0.0; d_model],
        g1: vec![1.0; d_model],
        b1n: vec![0.0; d_model],
        g2: vec![1.0; d_model],
        b2n: vec![0.0; d_model],
    }
}

/// Weights for a whole encoder stack (layer i seeded `seed + i`).
pub fn init_stack(seed: u64, d_model: usize, heads: usize, layers: usize) -> Vec<LayerWeights> {
    (0..layers).map(|i| init_layer(seed + i as u64, d_model, heads)).collect()
}

/// The decoder layer's cross-attention block: Q from the decoder stream,
/// K/V from the encoder memory, its own output projection and post-block
/// LayerNorm affine pair.
#[derive(Debug, Clone)]
pub struct CrossAttnWeights {
    /// Per-head projection panels, each `d_model x dk`.
    pub wq: Vec<Mat>,
    pub wk: Vec<Mat>,
    pub wv: Vec<Mat>,
    pub bq: Vec<Vec<f32>>,
    pub bk: Vec<Vec<f32>>,
    pub bv: Vec<Vec<f32>>,
    /// Cross output projection: `d_model x d_model`.
    pub wo: Mat,
    pub bo: Vec<f32>,
    /// Post-cross LayerNorm affine.
    pub g: Vec<f32>,
    pub bn: Vec<f32>,
}

/// One decoder layer: `base` carries the masked self-attention block
/// (its `wq..wo`, first LayerNorm) and the FFN chain (its `w1/w2`, second
/// LayerNorm) — the same shapes as an encoder layer — while `cross` holds
/// the middle cross-attention block.  `cross = None` is a GPT-style
/// decoder-only layer (no encoder memory).
#[derive(Debug, Clone)]
pub struct DecoderLayerWeights {
    pub base: LayerWeights,
    pub cross: Option<CrossAttnWeights>,
}

/// Deterministic weights for one decoder layer.
pub fn init_decoder_layer(seed: u64, d_model: usize, heads: usize, cross: bool) -> DecoderLayerWeights {
    let base = init_layer(seed, d_model, heads);
    let cross = cross.then(|| {
        assert_eq!(d_model % heads, 0, "execution weights need divisibility");
        let dk = d_model / heads;
        // Distinct stream from the base layer's so self and cross blocks
        // never share values.
        let mut rng = SplitMix64::new(seed ^ 0xc205_5a77);
        let s_attn = 1.0 / (d_model as f32).sqrt();
        let heads_mat = |rng: &mut SplitMix64| {
            (0..heads).map(|_| randn_mat(rng, d_model, dk, s_attn)).collect()
        };
        CrossAttnWeights {
            wq: heads_mat(&mut rng),
            wk: heads_mat(&mut rng),
            wv: heads_mat(&mut rng),
            bq: vec![vec![0.0; dk]; heads],
            bk: vec![vec![0.0; dk]; heads],
            bv: vec![vec![0.0; dk]; heads],
            wo: randn_mat(&mut rng, d_model, d_model, s_attn),
            bo: vec![0.0; d_model],
            g: vec![1.0; d_model],
            bn: vec![0.0; d_model],
        }
    });
    DecoderLayerWeights { base, cross }
}

/// Weights for a whole decoder stack (layer i seeded `seed + i`).
pub fn init_decoder_stack(
    seed: u64,
    d_model: usize,
    heads: usize,
    layers: usize,
    cross: bool,
) -> Vec<DecoderLayerWeights> {
    (0..layers).map(|i| init_decoder_layer(seed + i as u64, d_model, heads, cross)).collect()
}

/// Deterministic input activations `seq_len x d_model`.
pub fn init_input(seed: u64, seq_len: usize, d_model: usize) -> Mat {
    let mut rng = SplitMix64::new(seed ^ 0x5eed_1a7e);
    randn_mat(&mut rng, seq_len, d_model, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_calls() {
        let a = init_layer(7, 128, 2);
        let b = init_layer(7, 128, 2);
        assert_eq!(a.wo, b.wo);
        assert_eq!(a.wq[1], b.wq[1]);
        let c = init_layer(8, 128, 2);
        assert_ne!(a.wo, c.wo);
    }

    #[test]
    fn shapes_match_config() {
        let w = init_layer(0, 256, 4);
        assert_eq!(w.wq.len(), 4);
        assert_eq!((w.wq[0].rows, w.wq[0].cols), (256, 64));
        assert_eq!((w.w1.rows, w.w1.cols), (256, 1024));
        assert_eq!((w.w2.rows, w.w2.cols), (1024, 256));
        assert_eq!(w.g1.len(), 256);
    }

    #[test]
    fn init_scale_is_sane() {
        let w = init_layer(0, 256, 4);
        let rms = (w.wo.data.iter().map(|x| x * x).sum::<f32>() / w.wo.data.len() as f32).sqrt();
        let expect = 1.0 / (256f32).sqrt();
        assert!((rms / expect - 1.0).abs() < 0.1, "rms={rms} expect={expect}");
    }

    #[test]
    fn block_and_pad_roundtrip() {
        let m = Mat::from_fn(4, 6, |r, c| (r * 10 + c) as f32);
        let b = m.block(1, 2, 2, 3);
        assert_eq!(b.at(0, 0), 12.0);
        assert_eq!(b.at(1, 2), 24.0);
        let p = b.padded(4, 4);
        assert_eq!(p.at(0, 0), 12.0);
        assert_eq!(p.at(3, 3), 0.0);
        let mut z = Mat::zeros(4, 6);
        z.set_block(1, 2, &b);
        assert_eq!(z.at(2, 4), 24.0);
        assert_eq!(z.at(0, 0), 0.0);
    }

    #[test]
    #[should_panic]
    fn block_out_of_bounds_panics() {
        Mat::zeros(2, 2).block(1, 1, 2, 2);
    }

    #[test]
    fn decoder_weights_are_deterministic_and_distinct_from_base() {
        let a = init_decoder_layer(3, 128, 2, true);
        let b = init_decoder_layer(3, 128, 2, true);
        let ca = a.cross.as_ref().unwrap();
        let cb = b.cross.as_ref().unwrap();
        assert_eq!(ca.wo, cb.wo);
        assert_eq!(ca.wq[1], cb.wq[1]);
        // cross stream must not alias the self-attention stream
        assert_ne!(ca.wq[0], a.base.wq[0]);
        let solo = init_decoder_layer(3, 128, 2, false);
        assert!(solo.cross.is_none());
        assert_eq!(solo.base.wo, a.base.wo, "base stream is cross-independent");
        let stack = init_decoder_stack(9, 128, 2, 3, true);
        assert_eq!(stack.len(), 3);
        assert_ne!(stack[0].base.wo, stack[1].base.wo);
    }
}
