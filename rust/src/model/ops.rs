//! Exact operation / byte accounting for transformer inference — the
//! paper's GOPS and operational-intensity numbers (Table 1, Fig 12).
//!
//! Convention: one multiply-accumulate = 2 operations (the standard GOPS
//! accounting used by the accelerators the paper compares against).
//! Softmax/LayerNorm transcendental work is counted per element with the
//! paper's module decomposition, but matmuls dominate everything.

use super::TnnConfig;

/// Per-module operation counts for one encoder layer (matching the paper's
/// PM decomposition in Fig 2/3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerOps {
    /// QKV_PM: 3 projections, SL x d_model x d_model MACs total across heads.
    pub qkv: u64,
    /// QK_PM: h · SL² · d_k MACs plus the scale division per score.
    pub qk: u64,
    /// Softmax: exp + div per score element (counted as 2 ops each).
    pub softmax: u64,
    /// SV_PM: h · SL² · d_k MACs.
    pub sv: u64,
    /// FFN1_PM: attention output projection, SL · d² MACs.
    pub ffn1: u64,
    /// FFN2_PM: SL · d · hidden MACs (+ ReLU per element).
    pub ffn2: u64,
    /// FFN3_PM: SL · hidden · d MACs.
    pub ffn3: u64,
    /// Two LayerNorm passes: ~8 ops per element (mean, var, norm, affine).
    pub layernorm: u64,
    /// Bias additions for QKV + FFN outputs.
    pub bias: u64,
}

impl LayerOps {
    pub fn total(&self) -> u64 {
        self.qkv
            + self.qk
            + self.softmax
            + self.sv
            + self.ffn1
            + self.ffn2
            + self.ffn3
            + self.layernorm
            + self.bias
    }

    /// Attention share (MHA fraction — the paper cites 38–64 % [14, 15]).
    pub fn attention_fraction(&self) -> f64 {
        let attn = self.qkv + self.qk + self.softmax + self.sv;
        attn as f64 / self.total() as f64
    }
}

/// Operation counts for one encoder layer of `cfg`.
pub fn encoder_layer_ops(cfg: &TnnConfig) -> LayerOps {
    let sl = cfg.seq_len as u64;
    let d = cfg.d_model as u64;
    let h = cfg.heads as u64;
    let dk = cfg.dk() as u64;
    let hid = cfg.hidden as u64;
    LayerOps {
        qkv: 2 * 3 * sl * d * (h * dk), // 3 projections (h·dk ≈ d columns)
        qk: 2 * h * sl * sl * dk + h * sl * sl, // MACs + scale division
        softmax: 2 * h * sl * sl,       // exp + normalize per score
        sv: 2 * h * sl * sl * dk,
        ffn1: 2 * sl * d * d,
        ffn2: 2 * sl * d * hid + sl * hid, // + ReLU
        ffn3: 2 * sl * hid * d,
        layernorm: 2 * 8 * sl * d,
        bias: sl * (3 * h * dk + d + hid + d),
    }
}

/// Extra ops for one *decoder* layer: a second (cross) attention block.
pub fn decoder_layer_ops(cfg: &TnnConfig) -> u64 {
    let l = encoder_layer_ops(cfg);
    l.total() + l.qkv / 3 * 2 + l.qk + l.softmax + l.sv // Q from dec, K/V from enc
}

/// Total inference operations for the full stack.
pub fn total_ops(cfg: &TnnConfig) -> u64 {
    encoder_layer_ops(cfg).total() * cfg.enc_layers as u64
        + decoder_layer_ops(cfg) * cfg.dec_layers as u64
}

/// Giga-operations for the full stack (the paper's "GOP" unit).
pub fn total_gop(cfg: &TnnConfig) -> f64 {
    total_ops(cfg) as f64 / 1e9
}

/// Bytes that must cross the off-chip interface at least once per
/// inference: all weights + input/output activations (weights dominate;
/// activations stay on-chip in ADAPTOR's BRAMs).
pub fn offchip_bytes(cfg: &TnnConfig, bytes_per_elem: usize) -> u64 {
    let weights = cfg.total_params() as u64;
    let io = 2 * (cfg.seq_len * cfg.d_model) as u64;
    (weights + io) * bytes_per_elem as u64
}

/// Operational intensity (ops per off-chip byte) — the roofline x-axis.
pub fn operational_intensity(cfg: &TnnConfig, bytes_per_elem: usize) -> f64 {
    total_ops(cfg) as f64 / offchip_bytes(cfg, bytes_per_elem) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::presets;

    #[test]
    fn matmuls_dominate() {
        let c = presets::bert_base(64);
        let l = encoder_layer_ops(&c);
        let mm = l.qkv + l.qk + l.sv + l.ffn1 + l.ffn2 + l.ffn3;
        assert!(mm as f64 / l.total() as f64 > 0.97);
    }

    #[test]
    fn attention_fraction_matches_paper_range() {
        // "38% to 64% of this time is spent in MHA depending on the number
        // of tokens" — op share grows with SL.
        let short = encoder_layer_ops(&presets::bert_base(64)).attention_fraction();
        let long = encoder_layer_ops(&presets::bert_base(512)).attention_fraction();
        assert!(short > 0.2 && short < 0.45, "{short}");
        assert!(long > short, "attention share must grow with SL");
        assert!(long < 0.75, "{long}");
    }

    #[test]
    fn bert_base_gop_ballpark() {
        // BERT-base @ SL=64: ~11 GFLOPs-equivalent (2·params·SL plus attn).
        let g = total_gop(&presets::bert_base(64));
        assert!(g > 8.0 && g < 16.0, "{g}");
    }

    #[test]
    fn ops_scale_linearly_with_layers() {
        let c1 = presets::small_encoder(64, 1);
        let c4 = presets::small_encoder(64, 4);
        assert_eq!(4 * total_ops(&c1), total_ops(&c4));
    }

    #[test]
    fn attention_ops_scale_quadratically_with_sl() {
        let a = encoder_layer_ops(&presets::bert_base(64));
        let b = encoder_layer_ops(&presets::bert_base(128));
        assert_eq!(b.qk, 4 * a.qk);
        assert_eq!(b.sv, 4 * a.sv);
        assert_eq!(b.ffn2, 2 * a.ffn2); // linear parts double
    }

    #[test]
    fn decoder_layer_costs_more_than_encoder() {
        let c = presets::transformer_base(64);
        assert!(decoder_layer_ops(&c) > encoder_layer_ops(&c).total());
    }

    #[test]
    fn operational_intensity_increases_with_sl() {
        // weights are reused across SL positions: OI grows with SL.
        let lo = operational_intensity(&presets::bert_base(32), 4);
        let hi = operational_intensity(&presets::bert_base(128), 4);
        assert!(hi > lo);
    }

    #[test]
    fn quantization_raises_oi() {
        let f32_oi = operational_intensity(&presets::bert_base(64), 4);
        let i8_oi = operational_intensity(&presets::bert_base(64), 1);
        assert!((i8_oi / f32_oi - 4.0).abs() < 0.01);
    }
}
