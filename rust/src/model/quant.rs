//! Fixed-point datapath description ("fully quantized for computational
//! efficiency and portability", §1) and host-side symmetric int8
//! quantization utilities mirroring `python/compile/kernels/quant.py`.

/// Datapath bit width — the paper's `Bit_w` in Eq 25.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitWidth {
    Int8,
    Fixed16,
    Float32,
}

impl BitWidth {
    pub fn bits(self) -> usize {
        match self {
            BitWidth::Int8 => 8,
            BitWidth::Fixed16 => 16,
            BitWidth::Float32 => 32,
        }
    }

    pub fn bytes(self) -> usize {
        self.bits() / 8
    }
}

/// The paper synthesizes a fixed-point fabric; 16-bit is the evaluation
/// default (AXI loads convert float→fixed in 3 cc, §5.2).
pub const PAPER_DEFAULT: BitWidth = BitWidth::Fixed16;

pub const QMAX: f32 = 127.0;

/// Per-tensor symmetric scale: max|x| / 127, never zero.
pub fn calibrate_scale(xs: &[f32]) -> f32 {
    let m = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    (m / QMAX).max(1e-8)
}

/// Quantize-dequantize to the int8 lattice (matches the Pallas kernel's
/// round-half-away semantics of `jnp.round` for ties — banker's rounding).
pub fn quantize_dequantize(xs: &mut [f32], scale: f32) {
    for x in xs.iter_mut() {
        let q = (*x / scale).round_ties_even().clamp(-QMAX, QMAX);
        *x = q * scale;
    }
}

/// Max absolute quantization error for values inside the clip range.
pub fn max_inrange_error(scale: f32) -> f32 {
    scale / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_and_bytes() {
        assert_eq!(BitWidth::Int8.bytes(), 1);
        assert_eq!(BitWidth::Fixed16.bytes(), 2);
        assert_eq!(BitWidth::Float32.bytes(), 4);
    }

    #[test]
    fn qdq_is_idempotent_and_bounded() {
        let mut xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) / 100.0).collect();
        let s = calibrate_scale(&xs);
        let orig = xs.clone();
        quantize_dequantize(&mut xs, s);
        for (q, x) in xs.iter().zip(&orig) {
            assert!((q - x).abs() <= max_inrange_error(s) + 1e-6);
        }
        let once = xs.clone();
        quantize_dequantize(&mut xs, s);
        assert_eq!(once, xs);
    }

    #[test]
    fn calibrated_scale_prevents_clipping() {
        let xs = vec![-12.7f32, 3.3, 12.7];
        let s = calibrate_scale(&xs);
        assert!((s - 0.1).abs() < 1e-6);
        let mut q = xs.clone();
        quantize_dequantize(&mut q, s);
        assert!((q[2] - 12.7).abs() < 1e-5);
    }

    #[test]
    fn zero_input_has_nonzero_scale() {
        assert!(calibrate_scale(&[0.0; 4]) > 0.0);
    }
}
