//! Transformer topology descriptions and workload accounting.
//!
//! A [`TnnConfig`] is the unit the paper's runtime registers describe: the
//! *shape* of the network the fixed fabric must execute.  [`presets`] holds
//! the models the paper evaluates; [`ops`] counts operations and bytes the
//! way the paper's GOPS numbers do; [`quant`] describes the fixed-point
//! datapath; [`reference`] is a dense f32 CPU implementation used both as
//! the numerics oracle for the PJRT engine and as the CPU baseline.

pub mod ops;
pub mod presets;
pub mod quant;
pub mod reference;
pub mod weights;

/// Why a [`TnnConfig`] is structurally unusable — the typed causes behind
/// `validate`/`validate_for_execution`, so serving-boundary errors wrap a
/// matchable reason instead of a pre-formatted string.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigError {
    /// Some dimension register (seq_len/heads/d_model/hidden) is zero.
    ZeroDimension,
    /// Neither an encoder nor a decoder stack.
    NoLayers,
    /// The numeric engine requires `d_model % heads == 0`.
    HeadsDontDivide { d_model: usize, heads: usize },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::ZeroDimension => f.write_str("all dimensions must be nonzero"),
            ConfigError::NoLayers => f.write_str("need at least one encoder or decoder layer"),
            ConfigError::HeadsDontDivide { d_model, heads } => {
                write!(f, "d_model {d_model} not divisible by heads {heads}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

/// Pre-typed-error call sites (`Result<(), String>` chains) keep working.
impl From<ConfigError> for String {
    fn from(e: ConfigError) -> Self {
        e.to_string()
    }
}

/// A transformer topology — exactly the paper's runtime-programmable
/// parameter set (§3.12 configuration registers).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TnnConfig {
    /// Sequence length (`Sequence` register).
    pub seq_len: usize,
    /// Number of attention heads (`Heads` register).
    pub heads: usize,
    /// Embedding dimension (`Embeddings` register), `d_model`.
    pub d_model: usize,
    /// Intermediate (hidden) dimension (`Hidden` register); `4*d_model`
    /// in the standard transformer.
    pub hidden: usize,
    /// Number of encoder layers (`Layers_enc` register).
    pub enc_layers: usize,
    /// Number of decoder layers (`Layers_dec` register).
    pub dec_layers: usize,
}

impl TnnConfig {
    /// Encoder-only topology with the conventional `hidden = 4*d_model`.
    pub fn encoder(seq_len: usize, d_model: usize, heads: usize, enc_layers: usize) -> Self {
        Self { seq_len, heads, d_model, hidden: 4 * d_model, enc_layers, dec_layers: 0 }
    }

    /// Per-head dimension `d_k = d_model / h` (Eq 2 context). Rounds up for
    /// non-divisible topologies (the paper's custom encoder has
    /// `d_model = 200, h = 3`); the execution engine additionally requires
    /// exact divisibility, the analytical model does not.
    pub fn dk(&self) -> usize {
        self.d_model.div_ceil(self.heads)
    }

    /// Total attention + FFN sub-layers, encoder and decoder stacks
    /// combined (a decoder layer holds two attention blocks).
    pub fn layers(&self) -> usize {
        self.enc_layers + self.dec_layers
    }

    /// Structural sanity; returns the typed reason on failure (its
    /// `Display` is the human-readable message).
    pub fn validate(&self) -> std::result::Result<(), ConfigError> {
        if self.seq_len == 0 || self.heads == 0 || self.d_model == 0 || self.hidden == 0 {
            return Err(ConfigError::ZeroDimension);
        }
        if self.enc_layers == 0 && self.dec_layers == 0 {
            return Err(ConfigError::NoLayers);
        }
        Ok(())
    }

    /// Strict divisibility requirements of the *numeric* engine (the
    /// analytical/simulated models accept anything `validate` accepts).
    pub fn validate_for_execution(&self) -> std::result::Result<(), ConfigError> {
        self.validate()?;
        if self.d_model % self.heads != 0 {
            return Err(ConfigError::HeadsDontDivide { d_model: self.d_model, heads: self.heads });
        }
        Ok(())
    }

    /// Parameter count (weights + biases + LN affine) for one encoder layer.
    pub fn params_per_encoder_layer(&self) -> usize {
        let d = self.d_model;
        let h = self.hidden;
        // QKV + output projection + biases
        let attn = 3 * d * d + 3 * d + d * d + d;
        // FFN
        let ffn = d * h + h + h * d + d;
        // two LayerNorms
        let ln = 4 * d;
        attn + ffn + ln
    }

    /// Total parameter count across the stack (decoder layers counted with
    /// the extra cross-attention block).
    pub fn total_params(&self) -> usize {
        let d = self.d_model;
        let cross = 4 * d * d + 4 * d; // extra attention block per decoder layer
        self.enc_layers * self.params_per_encoder_layer()
            + self.dec_layers * (self.params_per_encoder_layer() + cross)
    }
}

impl std::fmt::Display for TnnConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "TNN(sl={}, d={}, h={}, ffn={}, enc={}, dec={})",
            self.seq_len, self.d_model, self.heads, self.hidden, self.enc_layers, self.dec_layers
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encoder_constructor_uses_4x_hidden() {
        let c = TnnConfig::encoder(64, 768, 12, 12);
        assert_eq!(c.hidden, 3072);
        assert_eq!(c.dk(), 64);
        assert!(c.validate().is_ok());
        assert!(c.validate_for_execution().is_ok());
    }

    #[test]
    fn dk_rounds_up_for_custom_encoder() {
        // the paper's Fig-11 custom encoder: d=200, h=3
        let c = TnnConfig::encoder(64, 200, 3, 2);
        assert_eq!(c.dk(), 67);
        assert!(c.validate().is_ok());
        assert!(c.validate_for_execution().is_err());
    }

    #[test]
    fn zero_dims_rejected() {
        let mut c = TnnConfig::encoder(64, 768, 12, 1);
        c.seq_len = 0;
        assert_eq!(c.validate(), Err(ConfigError::ZeroDimension));
        let c2 = TnnConfig { enc_layers: 0, dec_layers: 0, ..TnnConfig::encoder(64, 768, 12, 1) };
        assert_eq!(c2.validate(), Err(ConfigError::NoLayers));
    }

    #[test]
    fn config_errors_render_the_historical_messages() {
        assert_eq!(ConfigError::ZeroDimension.to_string(), "all dimensions must be nonzero");
        assert_eq!(
            ConfigError::NoLayers.to_string(),
            "need at least one encoder or decoder layer"
        );
        let e = TnnConfig::encoder(64, 200, 3, 2).validate_for_execution().unwrap_err();
        assert_eq!(e, ConfigError::HeadsDontDivide { d_model: 200, heads: 3 });
        assert_eq!(e.to_string(), "d_model 200 not divisible by heads 3");
        let s: String = e.into();
        assert!(s.contains("not divisible"));
    }

    #[test]
    fn bert_base_param_count_is_right_ballpark() {
        // BERT-base encoder stack: ~85M layer params (embeddings excluded).
        let c = TnnConfig::encoder(64, 768, 12, 12);
        let p = c.total_params();
        assert!(p > 80_000_000 && p < 90_000_000, "{p}");
    }

    #[test]
    fn decoder_layers_cost_more_params() {
        let enc = TnnConfig { dec_layers: 0, ..TnnConfig::encoder(64, 512, 8, 2) };
        let dec = TnnConfig { enc_layers: 0, dec_layers: 2, ..enc };
        assert!(dec.total_params() > enc.total_params());
    }
}
