//! Dense CPU baseline executor: times the reference implementation on the
//! host — the "general-purpose platform" side of the paper's §1 argument
//! and the speedup-shape comparator for the serving benches.

use std::time::Instant;

use crate::model::reference;
use crate::model::weights::{LayerWeights, Mat};

/// Result of one timed CPU inference.
#[derive(Debug, Clone, Copy)]
pub struct CpuRun {
    pub ms: f64,
    pub gops: f64,
}

/// Run `cfg`-shaped encoder inference on the CPU reference and time it.
pub fn run_encoder(
    x: &Mat,
    layers: &[LayerWeights],
    mask: &Mat,
    total_ops: u64,
) -> (Mat, CpuRun) {
    let t0 = Instant::now();
    let out = reference::encoder_stack(x, layers, mask);
    let dt = t0.elapsed().as_secs_f64();
    (out, CpuRun { ms: dt * 1e3, gops: total_ops as f64 / dt / 1e9 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ops, presets, weights};

    #[test]
    fn cpu_run_produces_finite_output_and_positive_gops() {
        let cfg = presets::small_encoder(16, 2);
        let ws = weights::init_stack(0, cfg.d_model, cfg.heads, cfg.enc_layers);
        let x = weights::init_input(0, cfg.seq_len, cfg.d_model);
        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        let (out, run) = run_encoder(&x, &ws, &mask, ops::total_ops(&cfg));
        assert_eq!((out.rows, out.cols), (16, 256));
        assert!(out.data.iter().all(|v| v.is_finite()));
        assert!(run.gops > 0.0 && run.ms > 0.0);
    }
}
