//! Literature datapoints, exactly as the paper uses them.
//!
//! Table 1 rows are transcribed verbatim from the paper; Fig 10 points are
//! reconstructed from the paper's stated ratios ("ADAPTOR is 1.2× and
//! 2.87× more power efficient than the NVIDIA K80 GPU and i7-8700K CPU")
//! anchored on ADAPTOR's own measured 11.8 W / GOPS values — each point
//! records whether it is verbatim or ratio-derived.

/// Design-entry method of a comparator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Hls,
    Hdl,
    Unknown,
}

/// One FPGA-accelerator comparison row (Table 1).
#[derive(Debug, Clone)]
pub struct FpgaRow {
    pub network: &'static str,
    pub accelerator: &'static str,
    pub citation: &'static str,
    pub dsp: u64,
    pub dsp_pct: f64,
    pub lut: u64,
    pub lut_pct: f64,
    pub gops: f64,
    pub power_w: Option<f64>,
    pub method: Method,
    /// Weight sparsity the design exploits (ADAPTOR: dense, 0.0).
    pub sparsity: Option<f64>,
}

impl FpgaRow {
    /// Normalized throughput: (GOPS/DSP)×1000 — Table 1's column.
    pub fn gops_per_kdsp(&self) -> f64 {
        self.gops / self.dsp as f64 * 1000.0
    }

    /// (GOPS/LUT)×1000.
    pub fn gops_per_klut(&self) -> f64 {
        self.gops / self.lut as f64 * 1000.0
    }

    /// GOPS/W where power is known.
    pub fn gops_per_watt(&self) -> Option<f64> {
        self.power_w.map(|p| self.gops / p)
    }
}

/// Table 1, verbatim (ADAPTOR rows included for rendering; the benches
/// additionally recompute ADAPTOR's rows from the model and print both).
pub fn table1() -> Vec<FpgaRow> {
    use Method::*;
    vec![
        FpgaRow { network: "Shallow Transformer", accelerator: "Fang et al.", citation: "[44]", dsp: 4160, dsp_pct: 0.34, lut: 464_000, lut_pct: 0.27, gops: 1467.0, power_w: Some(27.0), method: Hdl, sparsity: Some(0.75) },
        FpgaRow { network: "Shallow Transformer", accelerator: "Qi et al.", citation: "[19]", dsp: 3572, dsp_pct: 0.52, lut: 485_000, lut_pct: 0.41, gops: 14.0, power_w: None, method: Hls, sparsity: Some(0.80) },
        FpgaRow { network: "Shallow Transformer", accelerator: "Qi et al.", citation: "[33]", dsp: 5040, dsp_pct: 0.74, lut: 908_000, lut_pct: 0.76, gops: 12.0, power_w: None, method: Hls, sparsity: Some(0.86) },
        FpgaRow { network: "Shallow Transformer", accelerator: "ADAPTOR", citation: "(paper)", dsp: 3612, dsp_pct: 0.40, lut: 391_000, lut_pct: 0.30, gops: 27.0, power_w: Some(11.8), method: Hls, sparsity: Some(0.0) },
        FpgaRow { network: "Custom Transformer Encoder", accelerator: "Qi et al.", citation: "[33]", dsp: 4145, dsp_pct: 0.60, lut: 937_000, lut_pct: 0.79, gops: 75.94, power_w: None, method: Hls, sparsity: Some(0.0) },
        FpgaRow { network: "Custom Transformer Encoder", accelerator: "ADAPTOR", citation: "(paper)", dsp: 3612, dsp_pct: 0.40, lut: 391_000, lut_pct: 0.30, gops: 132.0, power_w: Some(11.8), method: Hls, sparsity: Some(0.0) },
        FpgaRow { network: "BERT", accelerator: "FTRANS", citation: "[18]", dsp: 6531, dsp_pct: 0.95, lut: 451_000, lut_pct: 0.38, gops: 1053.0, power_w: Some(25.06), method: Hls, sparsity: Some(0.93) },
        FpgaRow { network: "BERT", accelerator: "FQ-BERT", citation: "[43]", dsp: 1751, dsp_pct: 0.69, lut: 123_000, lut_pct: 0.45, gops: 254.0, power_w: Some(9.8), method: Hls, sparsity: Some(0.87) },
        FpgaRow { network: "BERT", accelerator: "Tzanos et al.", citation: "[45]", dsp: 5861, dsp_pct: 0.85, lut: 910_000, lut_pct: 0.77, gops: 65.7, power_w: None, method: Hls, sparsity: Some(0.0) },
        FpgaRow { network: "BERT", accelerator: "TRAC", citation: "[46]", dsp: 1379, dsp_pct: 0.80, lut: 126_000, lut_pct: 0.55, gops: 128.0, power_w: None, method: Hls, sparsity: None },
        FpgaRow { network: "BERT", accelerator: "ADAPTOR", citation: "(paper)", dsp: 3612, dsp_pct: 0.40, lut: 391_000, lut_pct: 0.30, gops: 40.0, power_w: Some(11.8), method: Hls, sparsity: Some(0.0) },
    ]
}

/// Platform category for Fig 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    Fpga,
}

/// One Fig 10 point: power and power efficiency per (device, model).
#[derive(Debug, Clone)]
pub struct PowerPoint {
    pub device: &'static str,
    pub kind: DeviceKind,
    pub model: &'static str,
    pub citation: &'static str,
    pub power_w: f64,
    pub gops_per_w: f64,
    /// true = transcribed number; false = reconstructed from the paper's
    /// stated ratio against ADAPTOR's anchor (11.8 W; 3.39/11/2.28 GOPS/W).
    pub verbatim: bool,
}

/// Fig 10's cross-platform power comparison.
pub fn fig10() -> Vec<PowerPoint> {
    use DeviceKind::*;
    vec![
        // --- BERT (anchor: ADAPTOR 3.39 GOPS/W @ 11.8 W)
        PowerPoint { device: "ADAPTOR (U55C)", kind: Fpga, model: "BERT", citation: "(paper)", power_w: 11.8, gops_per_w: 3.39, verbatim: true },
        PowerPoint { device: "JETSON TX2", kind: Gpu, model: "BERT", citation: "[18]", power_w: 7.5, gops_per_w: 45.0, verbatim: false },
        PowerPoint { device: "RTX 5000", kind: Gpu, model: "BERT", citation: "[42]", power_w: 118.0, gops_per_w: 5.09, verbatim: false },
        PowerPoint { device: "NVIDIA K80", kind: Gpu, model: "BERT", citation: "[43]", power_w: 149.0, gops_per_w: 2.83, verbatim: false },
        PowerPoint { device: "i7-8700K", kind: Cpu, model: "BERT", citation: "[42][43]", power_w: 95.0, gops_per_w: 1.18, verbatim: false },
        // --- Custom 4-layer encoder (anchor: ADAPTOR 11 GOPS/W)
        PowerPoint { device: "ADAPTOR (U55C)", kind: Fpga, model: "Custom Encoder", citation: "(paper)", power_w: 11.8, gops_per_w: 11.0, verbatim: true },
        PowerPoint { device: "i5-4460", kind: Cpu, model: "Custom Encoder", citation: "[30]", power_w: 84.0, gops_per_w: 11.0 / 5.1, verbatim: false },
        PowerPoint { device: "RTX 3060", kind: Gpu, model: "Custom Encoder", citation: "[30]", power_w: 170.0, gops_per_w: 11.0 / 1.63, verbatim: false },
        // --- Shallow transformer (anchor: ADAPTOR 2.28 GOPS/W)
        PowerPoint { device: "ADAPTOR (U55C)", kind: Fpga, model: "Shallow Transformer", citation: "(paper)", power_w: 11.8, gops_per_w: 2.28, verbatim: true },
        PowerPoint { device: "i9-9900X", kind: Cpu, model: "Shallow Transformer", citation: "[44]", power_w: 165.0, gops_per_w: 2.28 / 3.7, verbatim: false },
        PowerPoint { device: "JETSON NANO", kind: Gpu, model: "Shallow Transformer", citation: "[44]", power_w: 11.8 / 1.56, gops_per_w: 2.28 / 1.28, verbatim: false },
        PowerPoint { device: "RTX 2080", kind: Gpu, model: "Shallow Transformer", citation: "[44]", power_w: 225.0, gops_per_w: 2.28 / 4.4, verbatim: false },
        PowerPoint { device: "RTX 3090", kind: Gpu, model: "Shallow Transformer", citation: "[44]", power_w: 350.0, gops_per_w: 2.28 / 1.67, verbatim: false },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_adaptor_rows_match_derived_columns() {
        // (GOPS/DSP)×1000 column: ADAPTOR BERT row prints 11.
        let rows = table1();
        let bert = rows
            .iter()
            .find(|r| r.accelerator == "ADAPTOR" && r.network == "BERT")
            .unwrap();
        assert!((bert.gops_per_kdsp() - 11.0).abs() < 0.2, "{}", bert.gops_per_kdsp());
        assert!((bert.gops_per_klut() - 0.10).abs() < 0.01);
        assert!((bert.gops_per_watt().unwrap() - 3.39).abs() < 0.01);
    }

    #[test]
    fn paper_speedup_claims_hold_in_data() {
        // "1.9× and 2.25× higher GOPS compared to Qi et al. [19] and [33]"
        let rows = table1();
        let adaptor = rows.iter().find(|r| r.accelerator == "ADAPTOR" && r.network == "Shallow Transformer").unwrap();
        let qi19 = rows.iter().find(|r| r.citation == "[19]").unwrap();
        let qi33 = rows.iter().find(|r| r.citation == "[33]" && r.network == "Shallow Transformer").unwrap();
        assert!((adaptor.gops / qi19.gops - 1.93).abs() < 0.05);
        assert!((adaptor.gops / qi33.gops - 2.25).abs() < 0.05);
    }

    #[test]
    fn fig10_ratios_match_paper_statements() {
        let pts = fig10();
        let find = |d: &str, m: &str| pts.iter().find(|p| p.device == d && p.model == m).unwrap();
        let adaptor = find("ADAPTOR (U55C)", "BERT");
        let k80 = find("NVIDIA K80", "BERT");
        let i7 = find("i7-8700K", "BERT");
        assert!((adaptor.gops_per_w / k80.gops_per_w - 1.2).abs() < 0.02);
        assert!((adaptor.gops_per_w / i7.gops_per_w - 2.87).abs() < 0.03);
        // RTX 5000 is 1.5× MORE efficient but 10× more power
        let rtx = find("RTX 5000", "BERT");
        assert!((rtx.gops_per_w / adaptor.gops_per_w - 1.5).abs() < 0.02);
        assert!((rtx.power_w / adaptor.power_w - 10.0).abs() < 0.1);
    }

    #[test]
    fn adaptor_is_dense_everyone_fast_is_sparse() {
        // the paper's framing: comparable GOPS without sparsity.
        for r in table1() {
            if r.gops > 200.0 {
                assert!(r.sparsity.unwrap_or(1.0) > 0.5, "{} is fast but dense?", r.accelerator);
            }
        }
    }

    #[test]
    fn every_fig10_model_has_an_adaptor_anchor() {
        let pts = fig10();
        for m in ["BERT", "Custom Encoder", "Shallow Transformer"] {
            assert!(pts.iter().any(|p| p.model == m && p.device.starts_with("ADAPTOR") && p.verbatim));
        }
    }
}
