//! The comparison universe of the paper's evaluation.
//!
//! * [`literature`] — the exact datapoints the paper cites for Table 1 and
//!   Fig 10 (the paper itself compares against published numbers, not
//!   re-measured systems; we encode them with their citation keys).
//! * [`nonadaptive`] — an executable baseline: the "custom accelerator
//!   synthesized per model" that ADAPTOR's runtime adaptivity replaces
//!   (per-model optimal tiles, but a synthesis cost per topology change).
//! * [`cpu`] — a dense CPU executor (the reference implementation timed),
//!   used for speedup shapes and as the serving engine's oracle.

pub mod cpu;
pub mod literature;
pub mod nonadaptive;
