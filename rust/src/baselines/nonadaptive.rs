//! The non-adaptive baseline: a custom accelerator re-synthesized per
//! model — the workflow ADAPTOR's runtime adaptivity eliminates (§1: "Most
//! of these works ... their logic circuits go through the time-consuming
//! synthesis steps for different models").
//!
//! Per-model synthesis picks the best tile configuration for that single
//! topology (it can specialize!), but every topology change costs a full
//! HLS+implementation run — the paper quotes ≈36 hours for a SOTA
//! transformer (§3.10).  The ablation bench quantifies the tradeoff.

use crate::accel::{frequency, latency, resources, tiling::TileConfig};
use crate::accel::platform::Platform;
use crate::model::quant::BitWidth;
use crate::model::TnnConfig;

/// Paper §3.10: compilation time for a state-of-the-art transformer.
pub const SYNTHESIS_HOURS: f64 = 36.0;

/// Outcome of specializing a synthesis to one model.
#[derive(Debug, Clone)]
pub struct Specialized {
    pub tiles: TileConfig,
    pub freq_mhz: f64,
    pub latency_ms: f64,
    pub gops: f64,
}

/// Exhaustively pick the best legal tile configuration for `cfg` on
/// `platform` (what a per-model custom design would do).
pub fn specialize(cfg: &TnnConfig, platform: &Platform, bw: BitWidth) -> Option<Specialized> {
    let mut best: Option<Specialized> = None;
    for tiles_mha in 1..=48usize {
        for tiles_ffn in 1..=12usize {
            if cfg.d_model % tiles_mha != 0 || cfg.d_model % tiles_ffn != 0 {
                continue;
            }
            let ts = TileConfig::new(cfg.d_model / tiles_mha, cfg.d_model / tiles_ffn);
            let r = resources::estimate(cfg, &ts, bw, platform);
            if r.check_fit(platform).is_err() {
                continue;
            }
            let f = frequency::fmax_mhz(platform, &r);
            let lat = latency::model_latency(cfg, &ts);
            let ms = lat.ms_at(f);
            let cand = Specialized { tiles: ts, freq_mhz: f, latency_ms: ms, gops: lat.gops_at(cfg, f) };
            if best.as_ref().map(|b| cand.latency_ms < b.latency_ms).unwrap_or(true) {
                best = Some(cand);
            }
        }
    }
    best
}

/// Time to deploy a *sequence* of models (the adaptivity ablation):
/// ADAPTOR synthesizes once and reprograms registers (microseconds);
/// the non-adaptive flow re-synthesizes per distinct topology.
#[derive(Debug, Clone)]
pub struct DeploymentCost {
    pub models: usize,
    pub adaptor_synthesis_hours: f64,
    pub nonadaptive_synthesis_hours: f64,
    /// Sum of per-inference latencies (ms) for each flow.
    pub adaptor_inference_ms: f64,
    pub nonadaptive_inference_ms: f64,
}

/// Compare both flows over a model sequence on `platform` with ADAPTOR's
/// fixed `adaptor_tiles`.
pub fn deployment_cost(
    models: &[TnnConfig],
    platform: &Platform,
    adaptor_tiles: &TileConfig,
    bw: BitWidth,
) -> DeploymentCost {
    let mut adaptor_ms = 0.0;
    let mut nonadaptive_ms = 0.0;
    let mut distinct = std::collections::HashSet::new();
    for cfg in models {
        let r = resources::estimate(cfg, adaptor_tiles, bw, platform);
        let f = frequency::fmax_mhz(platform, &r);
        adaptor_ms += latency::model_latency(cfg, adaptor_tiles).ms_at(f);
        if let Some(s) = specialize(cfg, platform, bw) {
            nonadaptive_ms += s.latency_ms;
        } else {
            nonadaptive_ms += f64::INFINITY;
        }
        distinct.insert((cfg.seq_len, cfg.d_model, cfg.heads, cfg.hidden, cfg.enc_layers, cfg.dec_layers));
    }
    DeploymentCost {
        models: models.len(),
        adaptor_synthesis_hours: SYNTHESIS_HOURS, // once, ever
        nonadaptive_synthesis_hours: SYNTHESIS_HOURS * distinct.len() as f64,
        adaptor_inference_ms: adaptor_ms,
        nonadaptive_inference_ms: nonadaptive_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform;
    use crate::model::presets;

    #[test]
    fn specialization_beats_or_ties_fixed_tiles_on_latency() {
        let p = platform::u55c();
        let cfg = presets::shallow_transformer();
        let spec = specialize(&cfg, &p, BitWidth::Fixed16).unwrap();
        let fixed = TileConfig::paper_optimum();
        let r = resources::estimate(&cfg, &fixed, BitWidth::Fixed16, &p);
        let f = frequency::fmax_mhz(&p, &r);
        let fixed_ms = latency::model_latency(&cfg, &fixed).ms_at(f);
        assert!(spec.latency_ms <= fixed_ms * 1.001, "{} vs {}", spec.latency_ms, fixed_ms);
    }

    #[test]
    fn adaptor_wins_deployment_time_for_many_models() {
        let p = platform::u55c();
        let models = vec![
            presets::bert_base(64),
            presets::shallow_transformer(),
            presets::custom_encoder_4l(),
            presets::small_encoder(64, 4),
        ];
        let c = deployment_cost(&models, &p, &TileConfig::paper_optimum(), BitWidth::Fixed16);
        assert_eq!(c.nonadaptive_synthesis_hours, 4.0 * SYNTHESIS_HOURS);
        assert_eq!(c.adaptor_synthesis_hours, SYNTHESIS_HOURS);
        // inference gap is milliseconds; synthesis gap is days.
        let gap_hours = c.nonadaptive_synthesis_hours - c.adaptor_synthesis_hours;
        let inf_gap_hours = (c.nonadaptive_inference_ms - c.adaptor_inference_ms).abs() / 3.6e6;
        assert!(gap_hours > 1e4 * inf_gap_hours);
    }

    #[test]
    fn specialize_respects_device_fit() {
        // a big model on a small device must pick tiles that fit (or none).
        let z = platform::zcu102();
        if let Some(s) = specialize(&presets::bert_base(64), &z, BitWidth::Fixed16) {
            let r = resources::estimate(&presets::bert_base(64), &s.tiles, BitWidth::Fixed16, &z);
            assert!(r.check_fit(&z).is_ok());
        }
    }
}
