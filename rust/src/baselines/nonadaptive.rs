//! The non-adaptive baseline: a custom accelerator re-synthesized per
//! model — the workflow ADAPTOR's runtime adaptivity eliminates (§1: "Most
//! of these works ... their logic circuits go through the time-consuming
//! synthesis steps for different models").
//!
//! Per-model synthesis picks the best tile configuration for that single
//! topology (it can specialize!), but every topology change costs a full
//! HLS+implementation run — the paper quotes ≈36 hours for a SOTA
//! transformer (§3.10).  The ablation bench quantifies the tradeoff.

use crate::accel::platform::Platform;
use crate::accel::schedule::{AttentionMode, FabricConstants};
use crate::accel::sim::cycle;
use crate::accel::{frequency, latency, resources, tiling::TileConfig};
use crate::model::quant::BitWidth;
use crate::model::TnnConfig;

/// Paper §3.10: compilation time for a state-of-the-art transformer.
pub const SYNTHESIS_HOURS: f64 = 36.0;

/// Outcome of specializing a synthesis to one model.
#[derive(Debug, Clone)]
pub struct Specialized {
    pub tiles: TileConfig,
    pub freq_mhz: f64,
    pub latency_ms: f64,
    pub gops: f64,
    /// Schedule-grounded cycle count for the chosen design: the lowered
    /// `TileProgram` replayed through the cycle backend — the same source
    /// of truth the adaptive engine executes.  `None` when the topology
    /// cannot be lowered (non-divisible heads, non-4·d hidden, …); those
    /// models keep only the closed-form number.
    pub sched_cycles: Option<u64>,
}

/// Replay the tile schedule a specialized fabric would execute and return
/// its predicted cycles (schedule-grounded counterpart of
/// `latency::model_latency`).
pub fn schedule_cycles(cfg: &TnnConfig, tiles: &TileConfig) -> Option<u64> {
    let fc = FabricConstants {
        sl_max: cfg.seq_len,
        dk: cfg.dk(),
        ts_mha: tiles.ts_mha,
        ts_ffn: tiles.ts_ffn,
        ffn_col: 4 * tiles.ts_ffn,
        dmodel_max: cfg.d_model,
        hidden_max: cfg.hidden,
    };
    cycle::estimate(cfg, &fc, AttentionMode::Split, false, false)
        .ok()
        .map(|r| r.total_cycles)
}

/// Exhaustively pick the best legal tile configuration for `cfg` on
/// `platform` (what a per-model custom design would do).
pub fn specialize(cfg: &TnnConfig, platform: &Platform, bw: BitWidth) -> Option<Specialized> {
    let mut best: Option<Specialized> = None;
    for tiles_mha in 1..=48usize {
        for tiles_ffn in 1..=12usize {
            if cfg.d_model % tiles_mha != 0 || cfg.d_model % tiles_ffn != 0 {
                continue;
            }
            let ts = TileConfig::new(cfg.d_model / tiles_mha, cfg.d_model / tiles_ffn);
            let r = resources::estimate(cfg, &ts, bw, platform);
            if r.check_fit(platform).is_err() {
                continue;
            }
            let f = frequency::fmax_mhz(platform, &r);
            let lat = latency::model_latency(cfg, &ts);
            let ms = lat.ms_at(f);
            let cand = Specialized {
                tiles: ts,
                freq_mhz: f,
                latency_ms: ms,
                gops: lat.gops_at(cfg, f),
                sched_cycles: None,
            };
            if best.as_ref().map(|b| cand.latency_ms < b.latency_ms).unwrap_or(true) {
                best = Some(cand);
            }
        }
    }
    // Ground the winner in the executed schedule (once — not per candidate).
    if let Some(b) = best.as_mut() {
        b.sched_cycles = schedule_cycles(cfg, &b.tiles);
    }
    best
}

/// Time to deploy a *sequence* of models (the adaptivity ablation):
/// ADAPTOR synthesizes once and reprograms registers (microseconds);
/// the non-adaptive flow re-synthesizes per distinct topology.
#[derive(Debug, Clone)]
pub struct DeploymentCost {
    pub models: usize,
    pub adaptor_synthesis_hours: f64,
    pub nonadaptive_synthesis_hours: f64,
    /// Sum of per-inference latencies (ms) for each flow.
    pub adaptor_inference_ms: f64,
    pub nonadaptive_inference_ms: f64,
}

/// Compare both flows over a model sequence on `platform` with ADAPTOR's
/// fixed `adaptor_tiles`.
pub fn deployment_cost(
    models: &[TnnConfig],
    platform: &Platform,
    adaptor_tiles: &TileConfig,
    bw: BitWidth,
) -> DeploymentCost {
    let mut adaptor_ms = 0.0;
    let mut nonadaptive_ms = 0.0;
    let mut distinct = std::collections::HashSet::new();
    for cfg in models {
        let r = resources::estimate(cfg, adaptor_tiles, bw, platform);
        let f = frequency::fmax_mhz(platform, &r);
        adaptor_ms += latency::model_latency(cfg, adaptor_tiles).ms_at(f);
        if let Some(s) = specialize(cfg, platform, bw) {
            nonadaptive_ms += s.latency_ms;
        } else {
            nonadaptive_ms += f64::INFINITY;
        }
        distinct.insert((cfg.seq_len, cfg.d_model, cfg.heads, cfg.hidden, cfg.enc_layers, cfg.dec_layers));
    }
    DeploymentCost {
        models: models.len(),
        adaptor_synthesis_hours: SYNTHESIS_HOURS, // once, ever
        nonadaptive_synthesis_hours: SYNTHESIS_HOURS * distinct.len() as f64,
        adaptor_inference_ms: adaptor_ms,
        nonadaptive_inference_ms: nonadaptive_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform;
    use crate::model::presets;

    #[test]
    fn specialization_beats_or_ties_fixed_tiles_on_latency() {
        let p = platform::u55c();
        let cfg = presets::shallow_transformer();
        let spec = specialize(&cfg, &p, BitWidth::Fixed16).unwrap();
        let fixed = TileConfig::paper_optimum();
        let r = resources::estimate(&cfg, &fixed, BitWidth::Fixed16, &p);
        let f = frequency::fmax_mhz(&p, &r);
        let fixed_ms = latency::model_latency(&cfg, &fixed).ms_at(f);
        assert!(spec.latency_ms <= fixed_ms * 1.001, "{} vs {}", spec.latency_ms, fixed_ms);
    }

    #[test]
    fn adaptor_wins_deployment_time_for_many_models() {
        let p = platform::u55c();
        let models = vec![
            presets::bert_base(64),
            presets::shallow_transformer(),
            presets::custom_encoder_4l(),
            presets::small_encoder(64, 4),
        ];
        let c = deployment_cost(&models, &p, &TileConfig::paper_optimum(), BitWidth::Fixed16);
        assert_eq!(c.nonadaptive_synthesis_hours, 4.0 * SYNTHESIS_HOURS);
        assert_eq!(c.adaptor_synthesis_hours, SYNTHESIS_HOURS);
        // inference gap is milliseconds; synthesis gap is days.
        let gap_hours = c.nonadaptive_synthesis_hours - c.adaptor_synthesis_hours;
        let inf_gap_hours = (c.nonadaptive_inference_ms - c.adaptor_inference_ms).abs() / 3.6e6;
        assert!(gap_hours > 1e4 * inf_gap_hours);
    }

    #[test]
    fn specialized_winner_is_schedule_grounded() {
        // the winning design's cycles come from replaying its TileProgram;
        // they must agree with the iteration-level simulator (same pricing)
        // for a divisible topology...
        let p = platform::u55c();
        let cfg = presets::bert_base(64);
        let spec = specialize(&cfg, &p, BitWidth::Fixed16).unwrap();
        let sched = spec.sched_cycles.expect("BERT lowers cleanly");
        let sim = crate::accel::sim::simulate(&cfg, &spec.tiles);
        let err = (sched as f64 - sim.total_cycles as f64).abs() / sim.total_cycles as f64;
        assert!(err < 0.01, "sched={sched} sim={} err={err:.4}", sim.total_cycles);
        // ...and a non-divisible one (d=200, h=3) falls back to None.
        if let Some(s) = specialize(&presets::custom_encoder(), &p, BitWidth::Fixed16) {
            assert!(s.sched_cycles.is_none());
        }
    }

    #[test]
    fn specialize_respects_device_fit() {
        // a big model on a small device must pick tiles that fit (or none).
        let z = platform::zcu102();
        if let Some(s) = specialize(&presets::bert_base(64), &z, BitWidth::Fixed16) {
            let r = resources::estimate(&presets::bert_base(64), &s.tiles, BitWidth::Fixed16, &z);
            assert!(r.check_fit(&z).is_ok());
        }
    }
}
