//! Deterministic pseudo-random generation (splitmix64 core + Box–Muller
//! normals) — replaces `rand`/`rand_chacha` in this offline build.
//!
//! Weight reproducibility only needs determinism and reasonable spectral
//! quality, both of which splitmix64 provides; it is not a cryptographic
//! generator and is not used for anything security-relevant.

/// splitmix64 — Steele et al., "Fast splittable pseudorandom number
/// generators" (the standard seeding PRNG).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // modulo bias is irrelevant at our n << 2^64
        self.next_u64() % n.max(1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a buffer with scaled normals.
    pub fn fill_normal_f32(&mut self, buf: &mut [f32], scale: f32) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = SplitMix64::new(43);
        assert_ne!(SplitMix64::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            let v = r.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut r = SplitMix64::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.05, "var = {var}");
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn fill_scales() {
        let mut r = SplitMix64::new(5);
        let mut buf = vec![0f32; 4096];
        r.fill_normal_f32(&mut buf, 0.1);
        let rms = (buf.iter().map(|x| x * x).sum::<f32>() / buf.len() as f32).sqrt();
        assert!((rms - 0.1).abs() < 0.01, "rms = {rms}");
    }
}
