//! Mini bench harness (offline build: no criterion).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses this:
//! warmup + timed samples + robust summary, printed in a stable format the
//! perf log in EXPERIMENTS.md §Perf quotes directly — and, via
//! [`write_json`], dumped machine-readable (p50/p95/p99 per bench) so the
//! perf trajectory can be tracked across PRs (`BENCH_hotpath.json`).

use std::time::Instant;

use super::stats::{summarize, Summary};

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            self.name,
            fmt_time(s.p50),
            fmt_time(s.mean),
            fmt_time(s.p95),
            fmt_time(s.max),
        )
    }
}

pub fn header() -> String {
    format!("{:<44} {:>10} {:>10} {:>10} {:>10}", "benchmark", "p50", "mean", "p95", "max")
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Run `f` `samples` times (after `warmup` runs) and summarize.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: summarize(&times) }
}

/// Standard bench-main wrapper: prints the header, runs the closures,
/// prints one line each.
pub fn run_suite(title: &str, cases: Vec<BenchResult>) {
    println!("\n== {title} ==");
    println!("{}", header());
    for c in cases {
        println!("{}", c.line());
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Serialize results as machine-readable JSON (times in seconds; plain
/// `{}` float formatting round-trips f64 exactly).  Input order is kept.
pub fn to_json(results: &[BenchResult]) -> String {
    let mut s = String::from("{\n  \"version\": 1,\n  \"benches\": {\n");
    for (i, r) in results.iter().enumerate() {
        let m = &r.summary;
        s.push_str(&format!(
            "    \"{}\": {{\"n\": {}, \"p50_s\": {}, \"p95_s\": {}, \"p99_s\": {}, \
             \"mean_s\": {}, \"std_s\": {}, \"min_s\": {}, \"max_s\": {}}}{}\n",
            json_escape(&r.name),
            m.n,
            m.p50,
            m.p95,
            m.p99,
            m.mean,
            m.std,
            m.min,
            m.max,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    s.push_str("  }\n}\n");
    s
}

/// Write [`to_json`] to `path` — benches call this at the end of a normal
/// run (e.g. `BENCH_hotpath.json` from `benches/hotpath.rs`).
pub fn write_json(path: impl AsRef<std::path::Path>, results: &[BenchResult]) -> std::io::Result<()> {
    std::fs::write(path, to_json(results))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_times() {
        let r = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.summary.min >= 0.0);
        assert!(r.summary.n == 10);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn json_emission_is_parseable_and_complete() {
        let a = bench("fast/one", 0, 5, || {
            std::hint::black_box((0..10).sum::<u64>());
        });
        let b = bench("slow \"two\"", 0, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        let text = to_json(&[a, b]);
        let j = crate::util::json::parse(&text).expect("bench JSON must parse");
        let benches = j.get("benches").and_then(|b| b.as_obj()).unwrap();
        assert_eq!(benches.len(), 2);
        let one = benches.get("fast/one").unwrap();
        assert_eq!(one.get("n").unwrap().as_usize(), Some(5));
        for key in ["p50_s", "p95_s", "p99_s", "mean_s", "min_s", "max_s", "std_s"] {
            assert!(one.get(key).unwrap().as_f64().is_some(), "missing {key}");
        }
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
