//! Mini bench harness (offline build: no criterion).
//!
//! Each `cargo bench` target is a `harness = false` binary that uses this:
//! warmup + timed samples + robust summary, printed in a stable format the
//! perf log in EXPERIMENTS.md §Perf quotes directly.

use std::time::Instant;

use super::stats::{summarize, Summary};

/// Timing result for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn line(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10}",
            self.name,
            fmt_time(s.p50),
            fmt_time(s.mean),
            fmt_time(s.p95),
            fmt_time(s.max),
        )
    }
}

pub fn header() -> String {
    format!("{:<44} {:>10} {:>10} {:>10} {:>10}", "benchmark", "p50", "mean", "p95", "max")
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.2}us", secs * 1e6)
    } else {
        format!("{:.0}ns", secs * 1e9)
    }
}

/// Run `f` `samples` times (after `warmup` runs) and summarize.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), summary: summarize(&times) }
}

/// Standard bench-main wrapper: prints the header, runs the closures,
/// prints one line each.
pub fn run_suite(title: &str, cases: Vec<BenchResult>) {
    println!("\n== {title} ==");
    println!("{}", header());
    for c in cases {
        println!("{}", c.line());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_positive_times() {
        let r = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.summary.min >= 0.0);
        assert!(r.summary.n == 10);
        assert!(r.line().contains("noop-ish"));
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
