//! Small statistics kit for the in-tree bench harness (offline build: no
//! criterion): robust summary of timing samples.

/// Summary of a sample set (times in seconds or any unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Compute a summary; panics on empty input.
pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize() on empty sample set");
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| sorted[(((n - 1) as f64) * p).floor() as usize];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        p50: q(0.5),
        p95: q(0.95),
        p99: q(0.99),
        max: sorted[n - 1],
    }
}

/// Relative difference |a-b| / max(|a|,|b|, eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    (a - b).abs() / a.abs().max(b.abs()).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!((s.min, s.p50, s.p95, s.p99, s.max), (2.0, 2.0, 2.0, 2.0, 2.0));
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = summarize(&xs);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!((rel_diff(10.0, 11.0) - rel_diff(11.0, 10.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        summarize(&[]);
    }
}
