//! In-tree utility substrates.
//!
//! The build environment is fully offline (only the `xla` crate's vendored
//! closure is available), so the pieces a served system would usually pull
//! from crates.io are implemented here: a deterministic RNG ([`rng`]), a
//! minimal JSON parser for the artifact manifest ([`json`]), and a tiny
//! statistics kit for the bench harness ([`stats`]).

pub mod benchkit;
pub mod json;
pub mod rng;
pub mod stats;
