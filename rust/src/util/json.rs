//! Minimal JSON parser for the artifact manifest (offline build: no
//! serde_json).  Supports the full JSON grammar the manifest uses:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[ [1,2], [3,4] ]` -> `vec![vec![1,2], vec![3,4]]`.
    pub fn as_shape_list(&self) -> Option<Vec<Vec<usize>>> {
        self.as_arr()?
            .iter()
            .map(|a| a.as_arr().map(|dims| dims.iter().filter_map(Json::as_usize).collect()))
            .collect()
    }
}

pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("eof in \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => s.push(c as char),
                None => return Err("eof in string".into()),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = parse(
            r#"{"version": 1, "sl_max": 128,
                "artifacts": {"mm_qkv": {"file": "mm_qkv.hlo.txt",
                "inputs": [[128,64],[64,64],[128,64]], "outputs": [[128,64]]}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("sl_max").unwrap().as_usize(), Some(128));
        let mm = j.get("artifacts").unwrap().get("mm_qkv").unwrap();
        assert_eq!(mm.get("file").unwrap().as_str(), Some("mm_qkv.hlo.txt"));
        let shapes = mm.get("inputs").unwrap().as_shape_list().unwrap();
        assert_eq!(shapes, vec![vec![128, 64], vec![64, 64], vec![128, 64]]);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = parse(r#"{"s": "a\nb\t\"q\" A"}"#).unwrap();
        assert_eq!(j.get("s").unwrap().as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn numbers() {
        let j = parse(r#"[0, -1, 3.5, 1e3, -2.5e-2]"#).unwrap();
        let v: Vec<f64> = j.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(v, vec![0.0, -1.0, 3.5, 1000.0, -0.025]);
    }

    #[test]
    fn bool_null_empty() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":1} x").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn real_manifest_parses() {
        // integration sanity against the actual artifact manifest if built
        let p = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(p) {
            let j = parse(&text).unwrap();
            assert!(j.get("artifacts").unwrap().as_obj().unwrap().len() >= 13);
        }
    }
}
