"""Symmetric int8 fake-quantization Pallas kernel.

The paper's fabric is "fully quantized for computational efficiency and
portability" (fixed-point DSP48 datapaths).  On this substrate numerics run
in f32 on the PJRT CPU client, so quantization is modeled as
quantize-dequantize (QDQ): values are rounded to the int8 lattice scaled by
a per-tensor scale, which reproduces fixed-point rounding error exactly
while keeping artifacts executable on any PJRT backend.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK_ROWS_ATTN, INT8_QMAX


def _qdq_kernel(x_ref, s_ref, o_ref):
    scale = s_ref[0]
    q = jnp.clip(jnp.round(x_ref[...] / scale), -INT8_QMAX, INT8_QMAX)
    o_ref[...] = q * scale


@jax.jit
def quantize_dequantize(x, scale):
    """Round x to the int8 lattice with per-tensor `scale` (1,) and return
    the dequantized f32 values."""
    sl, d = x.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _qdq_kernel,
        grid=(sl // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, d), jnp.float32),
        interpret=True,
    )(x, scale)


def calibrate_scale(x) -> jnp.ndarray:
    """Per-tensor symmetric scale: max |x| / 127 (never zero)."""
    return jnp.maximum(jnp.max(jnp.abs(x)) / INT8_QMAX, 1e-8).reshape(1)
