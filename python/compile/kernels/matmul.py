"""Tiled matmul-accumulate Pallas kernel — the paper's MAC tile visit.

FPGA -> TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's PE array
(DSP48 MACs over a BRAM-resident weight tile) becomes an MXU-shaped block
matmul over VMEM-resident panels.  The grid's K axis is the paper's tile
loop (Fig 4): partial products accumulate into the output block across K
steps, exactly as ADAPTOR accumulates tile outputs "with those from
previous iterations in the next cycle" (sec. 3.9).

interpret=True everywhere: real-TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK_K, BLOCK_M, BLOCK_N


def _mm_acc_kernel(x_ref, w_ref, acc_ref, o_ref):
    """One (BM, BN) output block; K-axis of the grid accumulates."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = acc_ref[...]

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick(block: int, dim: int) -> int:
    """Largest block <= `block` that divides `dim` (dims here are powers of
    two times 64, so this terminates at a clean divisor)."""
    b = min(block, dim)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_acc(x, w, acc, *, bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K):
    """acc + x @ w with (bm, bn, bk) VMEM blocking.

    x: (M, K), w: (K, N), acc: (M, N) -> (M, N), all float32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and acc.shape == (m, n), (x.shape, w.shape, acc.shape)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, acc)


def _bias_kernel(x_ref, b_ref, o_ref, *, relu: bool):
    y = x_ref[...] + b_ref[...][None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("relu", "bn"))
def bias_add(x, b, *, relu: bool = False, bn: int = 512):
    """x + b (broadcast over rows), optional fused ReLU — Algorithms 15-17."""
    m, n = x.shape
    assert b.shape == (n,)
    bn = _pick(bn, n)
    return pl.pallas_call(
        functools.partial(_bias_kernel, relu=relu),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((m, bn), lambda j: (0, j)),
            pl.BlockSpec((bn,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, b)
