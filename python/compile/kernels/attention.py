"""Attention Pallas kernels: the paper's QK_PM, softmax unit, and SV_PM.

Two forms are provided, matching the two execution modes of the rust
coordinator:

* split kernels (`qk_scores`, `softmax_rows`, `sv`) — one per processing
  module, mirroring the paper's module decomposition (Fig 2) so the L3
  engine can schedule them exactly like the hardware does;
* a fused row-block kernel (`attention_head`) — the perf-path ablation: one
  VMEM-resident pass per row block (the TPU analog of chaining the three PE
  arrays without spilling S to BRAM).

Masking: `mask` is additive (0 on legal connections, SOFTMAX_NEG_INF on
illegal ones).  It encodes BOTH the decoder's causal mask (paper's Mask op,
Eq 1) and sequence-length padding — the runtime-adaptive `Sequence`
register on the rust side only changes this mask, never the artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK_ROWS_ATTN


def _qk_kernel(q_ref, k_ref, m_ref, s_ref, o_ref):
    s = jnp.dot(q_ref[...], k_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = s * s_ref[0] + m_ref[...]


@jax.jit
def qk_scores(q, k, mask, scale):
    """Mask(scale * Q K^T) — Algorithm 11 (QK_PM), row-block tiled.

    q, k: (SL, DK); mask: (SL, SL); scale: (1,) runtime input (Eq 1 uses
    1/sqrt(d_k); Algorithm 11 uses 1/d_model — the rust register file picks).
    """
    sl, dk = q.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _qk_kernel,
        grid=(sl // br,),
        in_specs=[
            pl.BlockSpec((br, dk), lambda i: (i, 0)),
            pl.BlockSpec((sl, dk), lambda i: (0, 0)),
            pl.BlockSpec((br, sl), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, sl), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, sl), jnp.float32),
        interpret=True,
    )(q, k, mask, scale)


def _softmax_kernel(s_ref, o_ref):
    s = s_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def softmax_rows(s):
    """Numerically-stable row softmax — Algorithm 7 (max, exp, normalize)."""
    sl, n = s.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(sl // br,),
        in_specs=[pl.BlockSpec((br, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, n), jnp.float32),
        interpret=True,
    )(s)


def _sv_kernel(p_ref, v_ref, o_ref):
    o_ref[...] = jnp.dot(p_ref[...], v_ref[...], preferred_element_type=jnp.float32)


@jax.jit
def sv(p, v):
    """S @ V — Algorithm 12 (SV_PM), row-block tiled."""
    sl, sl2 = p.shape
    _, dk = v.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _sv_kernel,
        grid=(sl // br,),
        in_specs=[
            pl.BlockSpec((br, sl2), lambda i: (i, 0)),
            pl.BlockSpec((sl2, dk), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, dk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, dk), jnp.float32),
        interpret=True,
    )(p, v)


def _attn_kernel(q_ref, k_ref, v_ref, m_ref, s_ref, o_ref):
    s = jnp.dot(q_ref[...], k_ref[...].T, preferred_element_type=jnp.float32)
    s = s * s_ref[0] + m_ref[...]
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v_ref[...], preferred_element_type=jnp.float32)


@jax.jit
def attention_head(q, k, v, mask, scale):
    """Fused scores+softmax+SV for one head (Eq 1), one pass per row block.

    K and V stay VMEM-resident across row blocks; S never leaves the block
    (the FPGA analog: S forwarded PE-to-PE instead of spilling to BRAM).
    """
    sl, dk = q.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _attn_kernel,
        grid=(sl // br,),
        in_specs=[
            pl.BlockSpec((br, dk), lambda i: (i, 0)),
            pl.BlockSpec((sl, dk), lambda i: (0, 0)),
            pl.BlockSpec((sl, dk), lambda i: (0, 0)),
            pl.BlockSpec((br, sl), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, dk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, dk), jnp.float32),
        interpret=True,
    )(q, k, v, mask, scale)


def _attn_packed_kernel(qkv_ref, m_ref, s_ref, o_ref, *, dk: int):
    q = qkv_ref[:, :dk]
    k = qkv_ref[:, dk:2 * dk]
    v = qkv_ref[:, 2 * dk:]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * s_ref[0] + m_ref[...]
    mx = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - mx)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def attention_head_packed(qkv, mask, scale):
    """Fused attention over a packed `[SL, 3*DK]` Q|K|V block — avoids the
    host-side split after the packed projection (§Perf iteration 3)."""
    sl, w = qkv.shape
    dk = w // 3
    return pl.pallas_call(
        functools.partial(_attn_packed_kernel, dk=dk),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((sl, w), lambda i: (0, 0)),
            pl.BlockSpec((sl, sl), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((sl, dk), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, dk), jnp.float32),
        interpret=True,
    )(qkv, mask, scale)


def padding_mask(sl_max: int, sl: int, causal: bool = False):
    """Additive mask for a runtime sequence length `sl` on an `sl_max`
    fabric; optionally causal (decoder masked self-attention)."""
    i = jnp.arange(sl_max)[:, None]
    j = jnp.arange(sl_max)[None, :]
    legal = (i < sl) & (j < sl)
    if causal:
        legal = legal & (j <= i)
    from ..configs import SOFTMAX_NEG_INF
    return jnp.where(legal, 0.0, SOFTMAX_NEG_INF).astype(jnp.float32)
