"""Decode-step (single-token) kernels: the KV-cached autoregressive path.

A decode step processes ONE activation row, so the SL_MAX-row Pallas
blocking of the prefill kernels degenerates to a single trivial block;
these kernels are therefore written as plain jnp programs (they lower to
the same single-block HLO the Pallas grid would emit, without the
interpret-mode dispatch overhead).  Shapes are fabric maxima like every
other tile primitive: the rust engine's masks/position inputs select the
active sub-volume at runtime.

Math contracts mirror the full-height kernels exactly:

* ``row_proj`` / ``row_proj_relu`` — ``x @ W + b`` (Algorithms 9/13/14/10
  collapsed to one visit: a 1xd row streams the whole weight matrix);
* ``qk_row`` — one query row against the full cached K panel, scaled then
  additively masked (Algorithm 11's row slice);
* ``softmax_row`` / ``sv_row`` — Algorithms 7/12 over one row;
* ``kv_append`` — write the new K/V row into the cache panel at the
  position given by the runtime scalar (the BRAM line write);
* ``residual_ln_row`` — the masked residual LayerNorm of
  ``layernorm.residual_ln`` on one row.
"""

import jax
import jax.numpy as jnp

from ..configs import LN_EPS


@jax.jit
def row_proj(x, w, b):
    """x @ w + b for one activation row (x: (1, D), w: (D, N), b: (N,))."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]


@jax.jit
def row_proj_relu(x, w, b):
    """row_proj with the FFN2 ReLU fused (Algorithm 17's row slice)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    return jnp.maximum(y, 0.0)


@jax.jit
def qk_row(q, k, mask, scale):
    """Mask(scale * q K^T) for one query row.

    q: (1, DK); k: (SL_MAX, DK); mask: (1, SL_MAX) additive; scale: (1,).
    """
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    return s * scale[0] + mask


@jax.jit
def softmax_row(s):
    """Numerically-stable softmax over one score row."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def sv_row(p, v):
    """p @ V for one probability row (p: (1, SL_MAX), v: (SL_MAX, DK))."""
    return jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def kv_append(cache, row, pos):
    """Write ``row`` into ``cache`` at row index ``pos`` (runtime scalar).

    cache: (SL_MAX, DK); row: (1, DK); pos: (1,) float32 position —
    dynamic_update_slice clamps out-of-range indices, matching the
    fabric's saturating address counter.
    """
    i = pos[0].astype(jnp.int32)
    return jax.lax.dynamic_update_slice(cache, row, (i, jnp.int32(0)))


@jax.jit
def residual_ln_row(x, res, gamma, beta, dmask, count):
    """Masked LayerNorm(x + res) over one row — the row slice of
    ``layernorm.residual_ln`` (identical arithmetic order)."""
    z = (x + res) * dmask[None, :]
    mu = jnp.sum(z, axis=-1, keepdims=True) / count[0]
    d = (z - mu) * dmask[None, :]
    var = jnp.sum(d * d, axis=-1, keepdims=True) / count[0]
    y = gamma[None, :] * (z - mu) * jax.lax.rsqrt(var + LN_EPS) + beta[None, :]
    return y * dmask[None, :]
