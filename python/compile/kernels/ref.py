"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness gate).

These implement the paper's equations directly (Eq 1-7) with no tiling, no
pallas, no tricks — pytest asserts each kernel matches its oracle to
float32 tolerance, and hypothesis sweeps shapes/values (python/tests/).
"""

import jax.numpy as jnp

from ..configs import INT8_QMAX, LN_EPS


def matmul_acc(x, w, acc):
    """acc + x @ w — one tile visit of the paper's MAC loops."""
    return acc + jnp.dot(x, w, preferred_element_type=jnp.float32)


def qk_scores(q, k, mask, scale):
    """Mask(scale * Q K^T) — Eq 1 numerator (scale passed explicitly;
    Algorithm 11 divides by d_model, Eq 1 by sqrt(d_k): callers choose)."""
    return jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale + mask


def softmax_rows(s):
    """Numerically-stable row softmax — Algorithm 7 (max, exp, normalize)."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def sv(p, v):
    """Attention-weighted values S @ V."""
    return jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention_head(q, k, v, mask, scale):
    """Full scaled-dot-product attention for one head — Eq 1."""
    return sv(softmax_rows(qk_scores(q, k, mask, scale)), v)


def bias_add(x, b):
    return x + b[None, :]


def bias_relu(x, b):
    """Eq 7 applied after bias — Algorithm 17."""
    return jnp.maximum(x + b[None, :], 0.0)


def gelu(x):
    """Eq 6 (erf formulation)."""
    from jax.scipy.special import erf
    return x * 0.5 * (1.0 + erf(x / jnp.sqrt(2.0).astype(x.dtype)))


def residual_ln(x, res, gamma, beta, dmask, count, eps=LN_EPS):
    """Masked LayerNorm(x + res) over the first `count` feature dims — Eq 4.

    dmask is 1.0 on valid feature columns, 0.0 on padding; count is the
    number of valid columns (a runtime register on the rust side).
    """
    z = (x + res) * dmask[None, :]
    mu = jnp.sum(z, axis=-1, keepdims=True) / count
    var = jnp.sum(((z - mu) * dmask[None, :]) ** 2, axis=-1, keepdims=True) / count
    y = gamma[None, :] * (z - mu) / jnp.sqrt(var + eps) + beta[None, :]
    return y * dmask[None, :]


def quantize_dequantize(x, scale):
    """Symmetric int8 fake-quant: round-to-nearest, clip to [-127, 127]."""
    q = jnp.clip(jnp.round(x / scale), -INT8_QMAX, INT8_QMAX)
    return q * scale
