"""Masked residual + LayerNorm Pallas kernel — the paper's LN unit (Eq 4,
Algorithm 8) with the runtime-adaptive twist: the valid feature width is a
runtime input (`count`, the `Embeddings` register), so one artifact serves
every embedding dimension up to DMODEL_MAX.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..configs import BLOCK_ROWS_ATTN, LN_EPS


def _ln_kernel(x_ref, r_ref, g_ref, b_ref, m_ref, c_ref, o_ref):
    z = (x_ref[...] + r_ref[...]) * m_ref[...][None, :]
    count = c_ref[0]
    mu = jnp.sum(z, axis=-1, keepdims=True) / count
    d = (z - mu) * m_ref[...][None, :]
    var = jnp.sum(d * d, axis=-1, keepdims=True) / count
    y = g_ref[...][None, :] * (z - mu) * jax.lax.rsqrt(var + LN_EPS) + b_ref[...][None, :]
    o_ref[...] = y * m_ref[...][None, :]


@jax.jit
def residual_ln(x, res, gamma, beta, dmask, count):
    """LayerNorm(x + res) over the first `count` of `d` columns.

    x, res: (SL, D); gamma, beta, dmask: (D,); count: (1,) float32.
    Rows are independent (position-wise, paper sec. 2.1), so the grid tiles
    rows; the full feature width stays in VMEM (<= 768 f32 = 3 KiB/row).
    """
    sl, d = x.shape
    br = min(BLOCK_ROWS_ATTN, sl)
    return pl.pallas_call(
        _ln_kernel,
        grid=(sl // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sl, d), jnp.float32),
        interpret=True,
    )(x, res, gamma, beta, dmask, count)
