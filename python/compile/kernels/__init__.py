"""L1 Pallas kernels (build-time only; lowered AOT into HLO artifacts).

Every kernel has a pure-jnp oracle in `ref.py`; pytest + hypothesis gate
correctness before any artifact is emitted.
"""

from .attention import (attention_head, attention_head_packed, padding_mask,
                        qk_scores, softmax_rows, sv)
from .decode import (kv_append, qk_row, residual_ln_row, row_proj,
                     row_proj_relu, softmax_row, sv_row)
from .layernorm import residual_ln
from .matmul import bias_add, matmul_acc
from .quant import calibrate_scale, quantize_dequantize

__all__ = [
    "attention_head",
    "attention_head_packed",
    "padding_mask",
    "qk_scores",
    "softmax_rows",
    "sv",
    "residual_ln",
    "bias_add",
    "matmul_acc",
    "quantize_dequantize",
    "calibrate_scale",
    "row_proj",
    "row_proj_relu",
    "qk_row",
    "softmax_row",
    "sv_row",
    "kv_append",
    "residual_ln_row",
]
