"""Shared compile-time ("synthesis-time") constants for the ADAPTOR artifact set.

These mirror the paper's synthesis-time parameters (section 3.10 / 6): the
tile sizes TS_MHA and TS_FFN are fixed when the fabric is synthesized; every
*runtime* parameter (sequence length, heads, embedding dim, hidden dim,
number of encoder/decoder layers) is adjusted afterwards purely in software
(rust configuration registers), never by re-lowering these artifacts.

The paper's defaults (section 6): d_model = 768, h = 12, N = 12, SL = 64,
TS_MHA = 64, TS_FFN = 128.  We additionally cap SL at SL_MAX = 128 — the
FPGA analog is BRAM buffers sized for the maximum sequence length, with the
runtime using a prefix (padding + masks select the active sub-volume).
"""

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# Synthesis-time (fixed once, like HLS tile sizes)
# ---------------------------------------------------------------------------

SL_MAX: int = 128          # max sequence length the fabric buffers support
TS_MHA: int = 64           # attention tile size (paper's optimum, sec. 3.10)
TS_FFN: int = 128          # FFN tile size (paper's optimum, sec. 3.10)
DK: int = 64               # per-head dim, fixed to 64 in base & big models
DMODEL_MAX: int = 768      # max embedding dim (BERT-base)
HIDDEN_MAX: int = 4 * DMODEL_MAX  # 3072
FFN_COL: int = 4 * TS_FFN  # FFN2 weight panel columns (paper: TS_FFN x 4TS_FFN)

SOFTMAX_NEG_INF: float = -1e9   # additive mask value for illegal connections
LN_EPS: float = 1e-5

# Pallas block shapes (VMEM tiles; see DESIGN.md §Hardware-Adaptation).
# §Perf iteration 1: the tile primitives' panels are at most 128x512 f32
# (256 KiB) — far below VMEM — so each artifact runs as a SINGLE block and
# the paper's tiling (Fig 4) lives entirely in the L3 schedule.  Interpret-
# mode grid loops (dynamic-update-slice chains) cost ~25x on the CPU PJRT
# path; see EXPERIMENTS.md §Perf.  Multi-block schedules remain covered by
# the explicit-block-shape property tests.
BLOCK_M: int = 512
BLOCK_N: int = 512
BLOCK_K: int = 512
BLOCK_ROWS_ATTN: int = 128  # row-block for the attention/LN/quant kernels
INT8_QMAX: float = 127.0


@dataclass(frozen=True)
class ArtifactSpec:
    """One AOT-lowered program: name, input shapes, output shapes (f32)."""

    name: str
    inputs: List[Tuple[int, ...]]
    outputs: List[Tuple[int, ...]]
    doc: str = ""

    def to_json(self) -> Dict:
        return {
            "file": f"{self.name}.hlo.txt",
            "inputs": [list(s) for s in self.inputs],
            "outputs": [list(s) for s in self.outputs],
            "doc": self.doc,
        }


def tile_primitive_specs() -> List[ArtifactSpec]:
    """The 'synthesized fabric': fixed-shape tile primitives.

    Shapes are maxima; runtime adaptivity = masks + loop bounds on the rust
    side, exactly as the paper's runtime registers re-bound HLS loops.
    """
    s = []
    s.append(ArtifactSpec(
        "mm_qkv",
        [(SL_MAX, TS_MHA), (TS_MHA, DK), (SL_MAX, DK)],
        [(SL_MAX, DK)],
        "acc + X_tile @ W_tile for Q/K/V projections (Algorithm 9, one tile)"))
    s.append(ArtifactSpec(
        "mm_qkv_packed",
        [(SL_MAX, TS_MHA), (TS_MHA, 3 * DK), (SL_MAX, 3 * DK)],
        [(SL_MAX, 3 * DK)],
        "one tile visit projecting a head's Q|K|V simultaneously "
        "(Algorithm 9's three MACs per cycle; §Perf iteration 3; the "
        "3*DK width is fabric-fixed, so no runtime topology wastes lanes)"))
    s.append(ArtifactSpec(
        "bias_add_qkv",
        [(SL_MAX, 3 * DK), (3 * DK,)],
        [(SL_MAX, 3 * DK)],
        "bias add over a head's packed Q|K|V block (Algorithm 15)"))
    s.append(ArtifactSpec(
        "attn_packed",
        [(SL_MAX, 3 * DK), (SL_MAX, SL_MAX), (1,)],
        [(SL_MAX, DK)],
        "attention straight from the packed Q|K|V block (on-device split; "
        "§Perf iteration 3)"))
    s.append(ArtifactSpec(
        "mm_ffn1",
        [(SL_MAX, TS_FFN), (TS_FFN, TS_FFN), (SL_MAX, TS_FFN)],
        [(SL_MAX, TS_FFN)],
        "FFN1 (attention output projection) tile matmul-accumulate (Algorithm 13)"))
    s.append(ArtifactSpec(
        "mm_ffn2",
        [(SL_MAX, TS_FFN), (TS_FFN, FFN_COL), (SL_MAX, FFN_COL)],
        [(SL_MAX, FFN_COL)],
        "FFN2 (d->4d) tile matmul-accumulate (Algorithm 14)"))
    s.append(ArtifactSpec(
        "mm_ffn3",
        [(SL_MAX, FFN_COL), (FFN_COL, TS_FFN), (SL_MAX, TS_FFN)],
        [(SL_MAX, TS_FFN)],
        "FFN3 (4d->d) tile matmul-accumulate (Algorithm 10)"))
    s.append(ArtifactSpec(
        "qk_scores",
        [(SL_MAX, DK), (SL_MAX, DK), (SL_MAX, SL_MAX), (1,)],
        [(SL_MAX, SL_MAX)],
        "scaled, masked Q.K^T (Algorithm 11 / QK_PM)"))
    s.append(ArtifactSpec(
        "softmax",
        [(SL_MAX, SL_MAX)],
        [(SL_MAX, SL_MAX)],
        "row softmax (Algorithm 7)"))
    s.append(ArtifactSpec(
        "sv",
        [(SL_MAX, SL_MAX), (SL_MAX, DK)],
        [(SL_MAX, DK)],
        "S @ V (Algorithm 12 / SV_PM)"))
    s.append(ArtifactSpec(
        "attn_fused",
        [(SL_MAX, DK), (SL_MAX, DK), (SL_MAX, DK), (SL_MAX, SL_MAX), (1,)],
        [(SL_MAX, DK)],
        "fused scores+softmax+SV (perf-path ablation of QK/softmax/SV split)"))
    s.append(ArtifactSpec(
        "bias_add_dk",
        [(SL_MAX, DK), (DK,)],
        [(SL_MAX, DK)],
        "bias add for per-head Q/K/V (Algorithm 15)"))
    s.append(ArtifactSpec(
        "bias_add_d",
        [(SL_MAX, DMODEL_MAX), (DMODEL_MAX,)],
        [(SL_MAX, DMODEL_MAX)],
        "bias add over full embedding dim (Algorithm 16)"))
    s.append(ArtifactSpec(
        "bias_relu_h",
        [(SL_MAX, HIDDEN_MAX), (HIDDEN_MAX,)],
        [(SL_MAX, HIDDEN_MAX)],
        "bias add + ReLU over hidden dim (Algorithm 17)"))
    s.append(ArtifactSpec(
        "residual_ln",
        [(SL_MAX, DMODEL_MAX), (SL_MAX, DMODEL_MAX), (DMODEL_MAX,),
         (DMODEL_MAX,), (DMODEL_MAX,), (1,)],
        [(SL_MAX, DMODEL_MAX)],
        "masked LayerNorm(x + residual) with runtime-valid dim count (Algorithm 8)"))
    s.append(ArtifactSpec(
        "bias_residual_ln",
        [(SL_MAX, DMODEL_MAX), (DMODEL_MAX,), (SL_MAX, DMODEL_MAX),
         (DMODEL_MAX,), (DMODEL_MAX,), (DMODEL_MAX,), (1,)],
        [(SL_MAX, DMODEL_MAX)],
        "fused Algorithm 16 + 8: bias add then masked residual LayerNorm in "
        "one dispatch (x, bias, residual, gamma, beta, dmask, count) — the "
        "dispatch-fusion target of the rust pass pipeline "
        "(accel::schedule::opt::FuseBiasLn)"))
    s.append(ArtifactSpec(
        "quantize",
        [(SL_MAX, DMODEL_MAX), (1,)],
        [(SL_MAX, DMODEL_MAX)],
        "int8 symmetric fake-quantization of activations"))
    # ---- decode-step (single-token) primitives: the KV-cached
    # autoregressive path.  One activation row fits a single BRAM line, so
    # the row datapath streams each full weight matrix in one visit
    # instead of walking SL_MAX-row panel tiles — which is what makes a
    # decode step strictly cheaper than re-running prefill.
    s.append(ArtifactSpec(
        "dec_qkv_row",
        [(1, DMODEL_MAX), (DMODEL_MAX, DK), (DK,)],
        [(1, DK)],
        "one token row's full Q/K/V projection + bias in one visit"))
    s.append(ArtifactSpec(
        "qk_row",
        [(1, DK), (SL_MAX, DK), (1, SL_MAX), (1,)],
        [(1, SL_MAX)],
        "one query row vs the cached K panel, scaled + masked "
        "(Algorithm 11's row slice; the mask row fences keys > pos)"))
    s.append(ArtifactSpec(
        "softmax_row",
        [(1, SL_MAX)],
        [(1, SL_MAX)],
        "row softmax of one score row (Algorithm 7)"))
    s.append(ArtifactSpec(
        "sv_row",
        [(1, SL_MAX), (SL_MAX, DK)],
        [(1, DK)],
        "one probability row @ cached V panel (Algorithm 12's row slice)"))
    s.append(ArtifactSpec(
        "kv_append",
        [(SL_MAX, DK), (1, DK), (1,)],
        [(SL_MAX, DK)],
        "append the new K/V row into the cache panel at the runtime "
        "position (the KV-cache BRAM line write)"))
    s.append(ArtifactSpec(
        "dec_proj_row",
        [(1, DMODEL_MAX), (DMODEL_MAX, DMODEL_MAX), (DMODEL_MAX,)],
        [(1, DMODEL_MAX)],
        "one row's full output projection + bias"))
    s.append(ArtifactSpec(
        "dec_ffn1_row",
        [(1, DMODEL_MAX), (DMODEL_MAX, HIDDEN_MAX), (HIDDEN_MAX,)],
        [(1, HIDDEN_MAX)],
        "one row's full FFN2 (d -> 4d) with bias + ReLU fused"))
    s.append(ArtifactSpec(
        "dec_ffn2_row",
        [(1, HIDDEN_MAX), (HIDDEN_MAX, DMODEL_MAX), (DMODEL_MAX,)],
        [(1, DMODEL_MAX)],
        "one row's full FFN3 (4d -> d) + bias"))
    s.append(ArtifactSpec(
        "residual_ln_row",
        [(1, DMODEL_MAX), (1, DMODEL_MAX), (DMODEL_MAX,),
         (DMODEL_MAX,), (DMODEL_MAX,), (1,)],
        [(1, DMODEL_MAX)],
        "masked residual LayerNorm of one row (Algorithm 8's row slice)"))
    return s


@dataclass(frozen=True)
class FusedConfig:
    """A per-model fused encoder layer — the non-adaptive baseline artifact
    (what a custom accelerator would synthesize for ONE model)."""

    name: str
    sl: int
    d_model: int
    heads: int
    quantized: bool = False

    @property
    def dk(self) -> int:
        return self.d_model // self.heads

    @property
    def hidden(self) -> int:
        return 4 * self.d_model


FUSED_CONFIGS: List[FusedConfig] = [
    FusedConfig("bert_layer", sl=64, d_model=768, heads=12),
    FusedConfig("small_layer", sl=64, d_model=256, heads=4),
    FusedConfig("small_layer_q", sl=64, d_model=256, heads=4, quantized=True),
]
