"""AOT lowering: JAX/Pallas -> HLO *text* artifacts + manifest.json.

This is the "synthesis" step of the reproduction (run once by
`make artifacts`).  It emits:

1. the tile-primitive fabric (configs.tile_primitive_specs) — fixed-shape
   programs the rust coordinator composes at runtime under the control of
   the configuration registers (runtime adaptivity, paper sec. 3.11/3.12);
2. fused per-config encoder layers (configs.FUSED_CONFIGS) — the
   non-adaptive "custom accelerator synthesized per model" baseline.

Interchange is HLO TEXT, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the `xla` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import hashlib
import json
import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import kernels, model
from .configs import (
    ArtifactSpec,
    FUSED_CONFIGS,
    FusedConfig,
    DK,
    DMODEL_MAX,
    FFN_COL,
    HIDDEN_MAX,
    SL_MAX,
    TS_FFN,
    TS_MHA,
    tile_primitive_specs,
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange).

    §Perf iteration 2: `return_tuple=False` — every artifact has exactly
    one output, and a bare array output lets the rust engine feed the
    result buffer straight back into the next dispatch (device-resident
    accumulator chaining) without a tuple unpack + host round-trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _f32(shape: Tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _primitive_fns() -> Dict[str, Callable]:
    """name -> jax function with the manifest's positional input order."""
    return {
        "mm_qkv": lambda x, w, acc: kernels.matmul_acc(x, w, acc),
        "mm_qkv_packed": lambda x, w, acc: kernels.matmul_acc(x, w, acc),
        "bias_add_qkv": lambda x, b: kernels.bias_add(x, b),
        "mm_ffn1": lambda x, w, acc: kernels.matmul_acc(x, w, acc),
        "mm_ffn2": lambda x, w, acc: kernels.matmul_acc(x, w, acc),
        "mm_ffn3": lambda x, w, acc: kernels.matmul_acc(x, w, acc),
        "qk_scores": lambda q, k, m, s: kernels.qk_scores(q, k, m, s),
        "softmax": kernels.softmax_rows,
        "sv": kernels.sv,
        "attn_fused": kernels.attention_head,
        "attn_packed": kernels.attention_head_packed,
        "bias_add_dk": lambda x, b: kernels.bias_add(x, b),
        "bias_add_d": lambda x, b: kernels.bias_add(x, b),
        "bias_relu_h": lambda x, b: kernels.bias_add(x, b, relu=True),
        "residual_ln": kernels.residual_ln,
        "bias_residual_ln": lambda x, b, res, g, bn, m, c: kernels.residual_ln(
            kernels.bias_add(x, b), res, g, bn, m, c
        ),
        "quantize": kernels.quantize_dequantize,
        # decode-step (single-token) primitives
        "dec_qkv_row": kernels.row_proj,
        "qk_row": kernels.qk_row,
        "softmax_row": kernels.softmax_row,
        "sv_row": kernels.sv_row,
        "kv_append": kernels.kv_append,
        "dec_proj_row": kernels.row_proj,
        "dec_ffn1_row": kernels.row_proj_relu,
        "dec_ffn2_row": kernels.row_proj,
        "residual_ln_row": kernels.residual_ln_row,
    }


def lower_primitive(spec: ArtifactSpec) -> str:
    fn = _primitive_fns()[spec.name]
    lowered = jax.jit(fn).lower(*[_f32(s) for s in spec.inputs])
    return to_hlo_text(lowered)


def _fused_fn(cfg: FusedConfig):
    def fn(x, mask, *flat):
        p = model.LayerParams(*flat)
        return model.encoder_layer(x, p, mask, quantized=cfg.quantized)

    return fn


def fused_input_shapes(cfg: FusedConfig) -> List[Tuple[int, ...]]:
    """x, mask, then LayerParams fields in declaration order."""
    d, h, dk, hid, sl = cfg.d_model, cfg.heads, cfg.dk, cfg.hidden, cfg.sl
    return [
        (sl, d), (sl, sl),
        (h, d, dk), (h, d, dk), (h, d, dk),          # wq wk wv
        (h, dk), (h, dk), (h, dk),                   # bq bk bv
        (d, d), (d,),                                # wo bo
        (d, hid), (hid,),                            # w1 b1
        (hid, d), (d,),                              # w2 b2
        (d,), (d,), (d,), (d,),                      # g1 b1n g2 b2n
    ]


def lower_fused(cfg: FusedConfig) -> str:
    shapes = fused_input_shapes(cfg)
    lowered = jax.jit(_fused_fn(cfg)).lower(*[_f32(s) for s in shapes])
    return to_hlo_text(lowered)


def source_digest() -> str:
    """Digest of the compile package, recorded in the manifest so the rust
    side can detect stale artifacts."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def build(out_dir: str, *, skip_fused: bool = False) -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: Dict = {
        "version": 2,
        "return_tuple": False,
        "digest": source_digest(),
        "sl_max": SL_MAX,
        "dk": DK,
        "ts_mha": TS_MHA,
        "ts_ffn": TS_FFN,
        "ffn_col": FFN_COL,
        "dmodel_max": DMODEL_MAX,
        "hidden_max": HIDDEN_MAX,
        "artifacts": {},
        "fused": {},
    }
    for spec in tile_primitive_specs():
        text = lower_primitive(spec)
        path = os.path.join(out_dir, f"{spec.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][spec.name] = spec.to_json()
        print(f"  lowered {spec.name:<14} -> {path} ({len(text)} chars)")
    if not skip_fused:
        for cfg in FUSED_CONFIGS:
            text = lower_fused(cfg)
            path = os.path.join(out_dir, f"fused_{cfg.name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            manifest["fused"][cfg.name] = {
                "file": f"fused_{cfg.name}.hlo.txt",
                "inputs": [list(s) for s in fused_input_shapes(cfg)],
                "outputs": [[cfg.sl, cfg.d_model]],
                "config": {
                    "sl": cfg.sl,
                    "d_model": cfg.d_model,
                    "heads": cfg.heads,
                    "quantized": cfg.quantized,
                },
            }
            print(f"  lowered fused_{cfg.name} -> {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--skip-fused", action="store_true",
                    help="only lower tile primitives (faster CI)")
    args = ap.parse_args()
    build(args.out, skip_fused=args.skip_fused)


if __name__ == "__main__":
    main()
