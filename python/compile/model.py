"""L2: the paper's transformer encoder/decoder forward pass in JAX, built on
the L1 Pallas kernels and decomposed exactly like ADAPTOR's processing
modules (Fig 1-3):

    QKV_PM -> bias -> QK_PM -> softmax -> SV_PM -> concat
    -> FFN1_PM (output projection) -> residual+LN
    -> FFN2_PM (d->4d, ReLU) -> FFN3_PM (4d->d) -> residual+LN

This module is build-time only: `aot.py` lowers the fused functions here to
HLO text once; the rust coordinator then runs them (or the per-module tile
primitives) via PJRT with Python absent from the request path.
"""

import math
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .configs import LN_EPS


class LayerParams(NamedTuple):
    """One encoder layer's weights, shaped like the paper's weight buffers.

    wq/wk/wv: (h, d_model, dk)   per-head projection panels
    bq/bk/bv: (h, dk)
    wo: (d_model, d_model), bo: (d_model,)          FFN1_PM
    w1: (d_model, hidden), b1: (hidden,)            FFN2_PM
    w2: (hidden, d_model), b2: (d_model,)           FFN3_PM
    g1/b1n, g2/b2n: (d_model,)                      the two LN units
    """

    wq: jnp.ndarray
    wk: jnp.ndarray
    wv: jnp.ndarray
    bq: jnp.ndarray
    bk: jnp.ndarray
    bv: jnp.ndarray
    wo: jnp.ndarray
    bo: jnp.ndarray
    w1: jnp.ndarray
    b1: jnp.ndarray
    w2: jnp.ndarray
    b2: jnp.ndarray
    g1: jnp.ndarray
    b1n: jnp.ndarray
    g2: jnp.ndarray
    b2n: jnp.ndarray


def init_layer_params(key, d_model: int, heads: int) -> LayerParams:
    """Deterministic synthetic weights (the accelerator is weight-agnostic;
    see DESIGN.md §Substitutions — HuggingFace .pth extraction replaced by
    a topology+synthetic-weight generator)."""
    dk = d_model // heads
    hidden = 4 * d_model
    ks = jax.random.split(key, 8)
    s_attn = 1.0 / math.sqrt(d_model)
    s_ffn1 = 1.0 / math.sqrt(d_model)
    s_ffn2 = 1.0 / math.sqrt(hidden)
    return LayerParams(
        wq=jax.random.normal(ks[0], (heads, d_model, dk), jnp.float32) * s_attn,
        wk=jax.random.normal(ks[1], (heads, d_model, dk), jnp.float32) * s_attn,
        wv=jax.random.normal(ks[2], (heads, d_model, dk), jnp.float32) * s_attn,
        bq=jnp.zeros((heads, dk), jnp.float32),
        bk=jnp.zeros((heads, dk), jnp.float32),
        bv=jnp.zeros((heads, dk), jnp.float32),
        wo=jax.random.normal(ks[3], (d_model, d_model), jnp.float32) * s_attn,
        bo=jnp.zeros((d_model,), jnp.float32),
        w1=jax.random.normal(ks[4], (d_model, hidden), jnp.float32) * s_ffn1,
        b1=jnp.zeros((hidden,), jnp.float32),
        w2=jax.random.normal(ks[5], (hidden, d_model), jnp.float32) * s_ffn2,
        b2=jnp.zeros((d_model,), jnp.float32),
        g1=jnp.ones((d_model,), jnp.float32),
        b1n=jnp.zeros((d_model,), jnp.float32),
        g2=jnp.ones((d_model,), jnp.float32),
        b2n=jnp.zeros((d_model,), jnp.float32),
    )


def _attention_block(x, p: LayerParams, mask, scale, quantized: bool):
    """MHA via the L1 kernels, head by head (the paper instantiates one
    QKV/QK/SV module set per head)."""
    sl, d_model = x.shape
    heads = p.wq.shape[0]
    outs = []
    for h in range(heads):
        q = kernels.bias_add(kernels.matmul_acc(x, p.wq[h], jnp.zeros((sl, p.wq.shape[2]), jnp.float32)), p.bq[h])
        k = kernels.bias_add(kernels.matmul_acc(x, p.wk[h], jnp.zeros((sl, p.wk.shape[2]), jnp.float32)), p.bk[h])
        v = kernels.bias_add(kernels.matmul_acc(x, p.wv[h], jnp.zeros((sl, p.wv.shape[2]), jnp.float32)), p.bv[h])
        outs.append(kernels.attention_head(q, k, v, mask, scale))
    attn = jnp.concatenate(outs, axis=-1)
    if quantized:
        attn = kernels.quantize_dequantize(attn, kernels.calibrate_scale(attn))
    return attn


def encoder_layer(x, p: LayerParams, mask, *, quantized: bool = False):
    """One full encoder layer (Eq 1-4) on the L1 kernels.

    x: (SL, d_model); mask: (SL, SL) additive.  Post-LN arrangement as the
    paper describes ("Residual addition and LN layers are inserted after
    each MHA and FFN").
    """
    sl, d_model = x.shape
    dk = p.wq.shape[2]
    scale = jnp.array([1.0 / math.sqrt(dk)], jnp.float32)
    ones = jnp.ones((d_model,), jnp.float32)
    count = jnp.array([float(d_model)], jnp.float32)

    attn = _attention_block(x, p, mask, scale, quantized)
    # FFN1_PM: attention output projection, then residual + LN.
    proj = kernels.bias_add(
        kernels.matmul_acc(attn, p.wo, jnp.zeros((sl, d_model), jnp.float32)), p.bo)
    y = kernels.residual_ln(proj, x, p.g1, p.b1n, ones, count)
    # FFN2_PM (ReLU) -> FFN3_PM, then residual + LN.
    hidden = kernels.bias_add(
        kernels.matmul_acc(y, p.w1, jnp.zeros((sl, p.w1.shape[1]), jnp.float32)),
        p.b1, relu=True)
    if quantized:
        hidden = kernels.quantize_dequantize(hidden, kernels.calibrate_scale(hidden))
    out = kernels.bias_add(
        kernels.matmul_acc(hidden, p.w2, jnp.zeros((sl, d_model), jnp.float32)), p.b2)
    return kernels.residual_ln(out, y, p.g2, p.b2n, ones, count)


def encoder_stack(x, layers, mask, *, quantized: bool = False):
    """N identical encoder layers; the input BRAM is 'reused to store the
    outputs of each encoder/decoder layer' (sec. 3.1) — plain chaining."""
    for p in layers:
        x = encoder_layer(x, p, mask, quantized=quantized)
    return x


# ---------------------------------------------------------------------------
# Decoder (paper Fig 1a: masked self-attention + cross-attention + FFN)
# ---------------------------------------------------------------------------

class DecoderParams(NamedTuple):
    self_attn: LayerParams          # masked self-attention + its FFN is unused
    cross: LayerParams              # cross-attention block reuses the layout


def decoder_layer(y, enc_out, p_self: LayerParams, p_cross: LayerParams,
                  causal_mask, cross_mask):
    """One decoder layer: masked self-attn, cross-attn over encoder output,
    position-wise FFN (each sub-layer with residual + LN)."""
    sl, d_model = y.shape
    dk = p_self.wq.shape[2]
    scale = jnp.array([1.0 / math.sqrt(dk)], jnp.float32)
    ones = jnp.ones((d_model,), jnp.float32)
    count = jnp.array([float(d_model)], jnp.float32)

    # Masked self-attention.
    sa = _attention_block(y, p_self, causal_mask, scale, False)
    sa = kernels.bias_add(
        kernels.matmul_acc(sa, p_self.wo, jnp.zeros((sl, d_model), jnp.float32)),
        p_self.bo)
    y1 = kernels.residual_ln(sa, y, p_self.g1, p_self.b1n, ones, count)

    # Cross-attention: Q from decoder state, K/V from encoder output.
    heads = p_cross.wq.shape[0]
    outs = []
    for h in range(heads):
        q = kernels.bias_add(kernels.matmul_acc(y1, p_cross.wq[h], jnp.zeros((sl, dk), jnp.float32)), p_cross.bq[h])
        k = kernels.bias_add(kernels.matmul_acc(enc_out, p_cross.wk[h], jnp.zeros((enc_out.shape[0], dk), jnp.float32)), p_cross.bk[h])
        v = kernels.bias_add(kernels.matmul_acc(enc_out, p_cross.wv[h], jnp.zeros((enc_out.shape[0], dk), jnp.float32)), p_cross.bv[h])
        s = kernels.qk_scores(q, k, cross_mask, scale)
        outs.append(kernels.sv(kernels.softmax_rows(s), v))
    ca = jnp.concatenate(outs, axis=-1)
    ca = kernels.bias_add(
        kernels.matmul_acc(ca, p_cross.wo, jnp.zeros((sl, d_model), jnp.float32)),
        p_cross.bo)
    y2 = kernels.residual_ln(ca, y1, p_cross.g1, p_cross.b1n, ones, count)

    # Position-wise FFN from the cross params.
    hidden = kernels.bias_add(
        kernels.matmul_acc(y2, p_cross.w1, jnp.zeros((sl, p_cross.w1.shape[1]), jnp.float32)),
        p_cross.b1, relu=True)
    out = kernels.bias_add(
        kernels.matmul_acc(hidden, p_cross.w2, jnp.zeros((sl, d_model), jnp.float32)),
        p_cross.b2)
    return kernels.residual_ln(out, y2, p_cross.g2, p_cross.b2n, ones, count)


# ---------------------------------------------------------------------------
# Pure-jnp reference model (oracle for the kernel-built model and for the
# rust engine's numerics — see python/tests/test_model.py)
# ---------------------------------------------------------------------------

def ref_encoder_layer(x, p: LayerParams, mask, *, quantized: bool = False):
    sl, d_model = x.shape
    heads, _, dk = p.wq.shape
    scale = 1.0 / math.sqrt(dk)
    outs = []
    for h in range(heads):
        q = x @ p.wq[h] + p.bq[h][None, :]
        k = x @ p.wk[h] + p.bk[h][None, :]
        v = x @ p.wv[h] + p.bv[h][None, :]
        outs.append(ref.attention_head(q, k, v, mask, scale))
    attn = jnp.concatenate(outs, axis=-1)
    if quantized:
        attn = ref.quantize_dequantize(attn, kernels.calibrate_scale(attn))
    proj = attn @ p.wo + p.bo[None, :]
    ones = jnp.ones((d_model,), jnp.float32)
    y = ref.residual_ln(proj, x, p.g1, p.b1n, ones, float(d_model))
    hidden = jnp.maximum(y @ p.w1 + p.b1[None, :], 0.0)
    if quantized:
        hidden = ref.quantize_dequantize(hidden, kernels.calibrate_scale(hidden))
    out = hidden @ p.w2 + p.b2[None, :]
    return ref.residual_ln(out, y, p.g2, p.b2n, ones, float(d_model))


def ref_encoder_stack(x, layers, mask, *, quantized: bool = False):
    for p in layers:
        x = ref_encoder_layer(x, p, mask, quantized=quantized)
    return x
