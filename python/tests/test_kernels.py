"""L1 correctness gate: every Pallas kernel vs its pure-jnp oracle.

Fixed-seed deterministic cases here; hypothesis shape/value sweeps live in
test_kernels_property.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.configs import DK, DMODEL_MAX, HIDDEN_MAX, SL_MAX, SOFTMAX_NEG_INF, TS_FFN, TS_MHA
from compile.kernels import ref


def rnd(seed, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale


class TestMatmulAcc:
    @pytest.mark.parametrize(
        "m,k,n",
        [
            (SL_MAX, TS_MHA, DK),        # mm_qkv shape
            (SL_MAX, TS_FFN, TS_FFN),    # mm_ffn1
            (SL_MAX, TS_FFN, 4 * TS_FFN),  # mm_ffn2
            (SL_MAX, 4 * TS_FFN, TS_FFN),  # mm_ffn3
            (64, 64, 64),
            (32, 128, 64),
        ],
    )
    def test_matches_ref(self, m, k, n):
        x, w, acc = rnd(0, (m, k)), rnd(1, (k, n)), rnd(2, (m, n))
        got = kernels.matmul_acc(x, w, acc)
        np.testing.assert_allclose(got, ref.matmul_acc(x, w, acc), rtol=1e-5, atol=1e-4)

    def test_zero_acc_is_plain_matmul(self):
        x, w = rnd(3, (64, 64)), rnd(4, (64, 64))
        got = kernels.matmul_acc(x, w, jnp.zeros((64, 64), jnp.float32))
        np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-4)

    def test_tile_accumulation_equals_full_matmul(self):
        """Fig 4a semantics: column-tiled partial products sum to the full
        projection — the core tiling invariant of the paper."""
        d_model, ts, dk = 256, 64, 64
        x, w = rnd(5, (32, d_model)), rnd(6, (d_model, dk))
        acc = jnp.zeros((32, dk), jnp.float32)
        for t in range(d_model // ts):
            acc = kernels.matmul_acc(x[:, t * ts:(t + 1) * ts], w[t * ts:(t + 1) * ts], acc)
        np.testing.assert_allclose(acc, x @ w, rtol=1e-4, atol=1e-3)

    def test_ffn_2d_tile_accumulation(self):
        """Fig 4b semantics: 2-D tiling accumulates along rows of W, writes
        disjoint column panels."""
        d, ts = 256, 128
        x, w = rnd(7, (32, d)), rnd(8, (d, 4 * d))
        out = jnp.zeros((32, 4 * d), jnp.float32)
        for c in range(4 * d // (4 * ts)):
            acc = jnp.zeros((32, 4 * ts), jnp.float32)
            for r in range(d // ts):
                acc = kernels.matmul_acc(
                    x[:, r * ts:(r + 1) * ts],
                    w[r * ts:(r + 1) * ts, c * 4 * ts:(c + 1) * 4 * ts],
                    acc,
                )
            out = out.at[:, c * 4 * ts:(c + 1) * 4 * ts].set(acc)
        np.testing.assert_allclose(out, x @ w, rtol=1e-4, atol=1e-3)


class TestBiasAdd:
    @pytest.mark.parametrize("n", [DK, TS_FFN, DMODEL_MAX, HIDDEN_MAX])
    def test_bias_add(self, n):
        x, b = rnd(0, (SL_MAX, n)), rnd(1, (n,))
        np.testing.assert_allclose(kernels.bias_add(x, b), ref.bias_add(x, b), rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("n", [TS_FFN, HIDDEN_MAX])
    def test_bias_relu(self, n):
        x, b = rnd(2, (SL_MAX, n)), rnd(3, (n,))
        got = kernels.bias_add(x, b, relu=True)
        np.testing.assert_allclose(got, ref.bias_relu(x, b), rtol=1e-6, atol=1e-6)
        assert float(jnp.min(got)) >= 0.0

    def test_relu_clamps_negatives_only(self):
        x = jnp.array([[-1.0, 0.0, 2.0, -3.0]], jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        got = kernels.bias_add(x, b, relu=True, bn=4)
        np.testing.assert_allclose(got, [[0.0, 0.0, 2.0, 0.0]])


class TestAttention:
    def test_qk_scores(self):
        q, k = rnd(0, (SL_MAX, DK)), rnd(1, (SL_MAX, DK))
        mask = kernels.padding_mask(SL_MAX, SL_MAX)
        scale = 1.0 / np.sqrt(DK)
        got = kernels.qk_scores(q, k, mask, jnp.array([scale], jnp.float32))
        np.testing.assert_allclose(got, ref.qk_scores(q, k, mask, scale), rtol=1e-4, atol=1e-3)

    def test_softmax_rows_sum_to_one(self):
        s = rnd(2, (SL_MAX, SL_MAX), 3.0)
        p = kernels.softmax_rows(s)
        np.testing.assert_allclose(p, ref.softmax_rows(s), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(jnp.sum(p, axis=-1), np.ones(SL_MAX), rtol=1e-5)

    def test_softmax_stability_large_values(self):
        """Algorithm 7 subtracts the row max exactly to survive this."""
        s = jnp.full((32, 32), 500.0, jnp.float32)
        p = kernels.softmax_rows(s)
        assert bool(jnp.all(jnp.isfinite(p)))
        np.testing.assert_allclose(p, np.full((32, 32), 1 / 32), rtol=1e-5)

    def test_sv(self):
        p, v = ref.softmax_rows(rnd(3, (SL_MAX, SL_MAX))), rnd(4, (SL_MAX, DK))
        np.testing.assert_allclose(kernels.sv(p, v), ref.sv(p, v), rtol=1e-4, atol=1e-4)

    def test_fused_equals_split(self):
        """The perf-path fused kernel must be numerically identical to the
        QK_PM -> softmax -> SV_PM module chain."""
        q, k, v = rnd(5, (SL_MAX, DK)), rnd(6, (SL_MAX, DK)), rnd(7, (SL_MAX, DK))
        mask = kernels.padding_mask(SL_MAX, 100)
        scale = jnp.array([1.0 / np.sqrt(DK)], jnp.float32)
        fused = kernels.attention_head(q, k, v, mask, scale)
        split = kernels.sv(kernels.softmax_rows(kernels.qk_scores(q, k, mask, scale)), v)
        np.testing.assert_allclose(fused[:100], split[:100], rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("sl", [1, 7, 64, 100, SL_MAX])
    def test_runtime_sequence_length_padding(self, sl):
        """The `Sequence` register contract: results on the valid prefix are
        independent of the padded region."""
        q, k, v = rnd(8, (SL_MAX, DK)), rnd(9, (SL_MAX, DK)), rnd(10, (SL_MAX, DK))
        mask = kernels.padding_mask(SL_MAX, sl)
        scale = jnp.array([1.0 / np.sqrt(DK)], jnp.float32)
        out = kernels.attention_head(q, k, v, mask, scale)[:sl]
        exact = ref.attention_head(q[:sl], k[:sl], v[:sl],
                                   jnp.zeros((sl, sl), jnp.float32), float(scale[0]))
        np.testing.assert_allclose(out, exact, rtol=1e-4, atol=1e-4)

    def test_causal_mask_is_lower_triangular(self):
        m = kernels.padding_mask(8, 8, causal=True)
        legal = np.asarray(m) == 0.0
        assert np.array_equal(legal, np.tril(np.ones((8, 8), bool)))
        m2 = kernels.padding_mask(8, 5, causal=True)
        legal2 = np.asarray(m2) == 0.0
        assert not legal2[0, 1] and legal2[4, 4] and not legal2[5, 5]

    def test_causal_attention_ignores_future(self):
        """Perturbing future tokens must not change earlier outputs."""
        q, k, v = rnd(11, (32, DK)), rnd(12, (32, DK)), rnd(13, (32, DK))
        mask = kernels.padding_mask(32, 32, causal=True)
        scale = jnp.array([0.125], jnp.float32)
        base = kernels.attention_head(q, k, v, mask, scale)
        k2 = k.at[20:].add(5.0)
        v2 = v.at[20:].add(-3.0)
        pert = kernels.attention_head(q, k2, v2, mask, scale)
        np.testing.assert_allclose(base[:20], pert[:20], rtol=1e-5, atol=1e-5)


class TestLayerNorm:
    def test_matches_ref_full_width(self):
        x, r = rnd(0, (SL_MAX, DMODEL_MAX)), rnd(1, (SL_MAX, DMODEL_MAX))
        g, b = rnd(2, (DMODEL_MAX,)) + 1.0, rnd(3, (DMODEL_MAX,))
        ones = jnp.ones((DMODEL_MAX,), jnp.float32)
        got = kernels.residual_ln(x, r, g, b, ones, jnp.array([float(DMODEL_MAX)], jnp.float32))
        np.testing.assert_allclose(
            got, ref.residual_ln(x, r, g, b, ones, float(DMODEL_MAX)), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("valid", [64, 200, 512, 768])
    def test_runtime_embedding_width(self, valid):
        """The `Embeddings` register contract: masked LN over a prefix equals
        exact LN on the truncated tensor."""
        x, r = rnd(4, (64, DMODEL_MAX)), rnd(5, (64, DMODEL_MAX))
        g = jnp.ones((DMODEL_MAX,), jnp.float32)
        b = jnp.zeros((DMODEL_MAX,), jnp.float32)
        dm = (jnp.arange(DMODEL_MAX) < valid).astype(jnp.float32)
        got = kernels.residual_ln(x * dm, r * dm, g, b, dm, jnp.array([float(valid)], jnp.float32))
        z = (x + r)[:, :valid]
        mu = z.mean(-1, keepdims=True)
        var = z.var(-1, keepdims=True)
        exact = (z - mu) / jnp.sqrt(var + 1e-5)
        np.testing.assert_allclose(got[:, :valid], exact, rtol=1e-3, atol=1e-3)
        if valid < DMODEL_MAX:
            assert float(jnp.abs(got[:, valid:]).max()) == 0.0

    def test_normalized_stats(self):
        x = rnd(6, (32, 256), 5.0)
        ones = jnp.ones((256,), jnp.float32)
        got = kernels.residual_ln(x, jnp.zeros_like(x), ones,
                                  jnp.zeros((256,), jnp.float32), ones,
                                  jnp.array([256.0], jnp.float32))
        np.testing.assert_allclose(got.mean(-1), np.zeros(32), atol=1e-4)
        np.testing.assert_allclose(got.std(-1), np.ones(32), rtol=1e-2)


class TestQuant:
    def test_matches_ref(self):
        x = rnd(0, (SL_MAX, DMODEL_MAX), 2.0)
        s = jnp.array([0.05], jnp.float32)
        np.testing.assert_allclose(
            kernels.quantize_dequantize(x, s), ref.quantize_dequantize(x, 0.05), atol=1e-6)

    def test_values_on_lattice(self):
        x = rnd(1, (32, 64))
        s = 0.1
        q = kernels.quantize_dequantize(x, jnp.array([s], jnp.float32))
        lattice = np.round(np.asarray(q) / s)
        np.testing.assert_allclose(np.asarray(q) / s, lattice, atol=1e-5)
        assert np.abs(lattice).max() <= 127

    def test_error_bounded_by_half_step(self):
        x = rnd(2, (32, 64))  # values within clip range for s=0.05
        s = 0.05
        q = kernels.quantize_dequantize(x, jnp.array([s], jnp.float32))
        inside = np.abs(np.asarray(x)) <= 127 * s
        err = np.abs(np.asarray(q) - np.asarray(x))[inside]
        assert err.max() <= s / 2 + 1e-6

    def test_calibrate_scale_covers_range(self):
        x = rnd(3, (16, 16), 10.0)
        s = kernels.calibrate_scale(x)
        q = kernels.quantize_dequantize(x, s)
        # calibrated scale => no clipping: max error is half a step
        assert float(jnp.abs(q - x).max()) <= float(s[0]) / 2 + 1e-6

    def test_idempotent(self):
        x = rnd(4, (16, 16))
        s = jnp.array([0.1], jnp.float32)
        q1 = kernels.quantize_dequantize(x, s)
        q2 = kernels.quantize_dequantize(q1, s)
        np.testing.assert_allclose(q1, q2, atol=1e-6)


class TestAttentionPacked:
    """§Perf iteration 3 kernel: attention over a packed Q|K|V block."""

    def test_matches_unpacked(self):
        q, k, v = rnd(50, (SL_MAX, DK)), rnd(51, (SL_MAX, DK)), rnd(52, (SL_MAX, DK))
        qkv = jnp.concatenate([q, k, v], axis=1)
        mask = kernels.padding_mask(SL_MAX, 96)
        scale = jnp.array([1.0 / np.sqrt(DK)], jnp.float32)
        packed = kernels.attention_head_packed(qkv, mask, scale)
        unpacked = kernels.attention_head(q, k, v, mask, scale)
        np.testing.assert_allclose(packed[:96], unpacked[:96], rtol=1e-5, atol=1e-5)

    def test_matches_ref(self):
        q, k, v = rnd(53, (64, DK)), rnd(54, (64, DK)), rnd(55, (64, DK))
        qkv = jnp.concatenate([q, k, v], axis=1)
        mask = kernels.padding_mask(64, 64)
        scale = jnp.array([0.125], jnp.float32)
        got = kernels.attention_head_packed(qkv, mask, scale)
        want = ref.attention_head(q, k, v, mask, 0.125)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
