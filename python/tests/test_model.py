"""L2 gate: kernel-built encoder/decoder vs the pure-jnp reference model,
plus shape & topology checks for every fused AOT config.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels, model
from compile.configs import FUSED_CONFIGS


def make(d_model, heads, seed=0):
    return model.init_layer_params(jax.random.PRNGKey(seed), d_model, heads)


class TestEncoderLayer:
    @pytest.mark.parametrize("d,h,sl", [(256, 4, 64), (128, 2, 32), (768, 12, 64)])
    def test_matches_ref(self, d, h, sl):
        p = make(d, h)
        x = jax.random.normal(jax.random.PRNGKey(1), (sl, d), jnp.float32)
        mask = kernels.padding_mask(sl, sl)
        got = model.encoder_layer(x, p, mask)
        want = model.ref_encoder_layer(x, p, mask)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_quantized_matches_ref(self):
        p = make(256, 4)
        x = jax.random.normal(jax.random.PRNGKey(2), (64, 256), jnp.float32)
        mask = kernels.padding_mask(64, 64)
        got = model.encoder_layer(x, p, mask, quantized=True)
        want = model.ref_encoder_layer(x, p, mask, quantized=True)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_quantization_error_is_small_but_nonzero(self):
        p = make(256, 4)
        x = jax.random.normal(jax.random.PRNGKey(3), (64, 256), jnp.float32)
        mask = kernels.padding_mask(64, 64)
        f = model.encoder_layer(x, p, mask, quantized=False)
        q = model.encoder_layer(x, p, mask, quantized=True)
        err = float(jnp.abs(f - q).max())
        assert 0.0 < err < 0.35, err  # int8 QDQ: visible but bounded

    def test_output_is_layernormed(self):
        p = make(128, 2)
        x = jax.random.normal(jax.random.PRNGKey(4), (32, 128), jnp.float32)
        y = model.encoder_layer(x, p, kernels.padding_mask(32, 32))
        np.testing.assert_allclose(np.asarray(y).mean(-1), np.zeros(32), atol=1e-4)
        np.testing.assert_allclose(np.asarray(y).std(-1), np.ones(32), rtol=2e-2)

    def test_stack_matches_ref(self):
        layers = [make(128, 2, s) for s in range(3)]
        x = jax.random.normal(jax.random.PRNGKey(5), (32, 128), jnp.float32)
        mask = kernels.padding_mask(32, 32)
        got = model.encoder_stack(x, layers, mask)
        want = model.ref_encoder_stack(x, layers, mask)
        np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


class TestDecoderLayer:
    def test_shapes_and_finiteness(self):
        d, h, sl = 128, 2, 32
        ps, pc = make(d, h, 0), make(d, h, 1)
        y = jax.random.normal(jax.random.PRNGKey(6), (sl, d), jnp.float32)
        enc = jax.random.normal(jax.random.PRNGKey(7), (sl, d), jnp.float32)
        causal = kernels.padding_mask(sl, sl, causal=True)
        cross = kernels.padding_mask(sl, sl)
        out = model.decoder_layer(y, enc, ps, pc, causal, cross)
        assert out.shape == (sl, d)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_causality(self):
        """Changing future decoder inputs must not change earlier outputs
        through the masked self-attention path."""
        d, h, sl = 128, 2, 16
        ps, pc = make(d, h, 0), make(d, h, 1)
        enc = jax.random.normal(jax.random.PRNGKey(8), (sl, d), jnp.float32)
        y = jax.random.normal(jax.random.PRNGKey(9), (sl, d), jnp.float32)
        causal = kernels.padding_mask(sl, sl, causal=True)
        cross = kernels.padding_mask(sl, sl)
        base = model.decoder_layer(y, enc, ps, pc, causal, cross)
        y2 = y.at[10:].add(3.0)
        pert = model.decoder_layer(y2, enc, ps, pc, causal, cross)
        # LN/FFN are position-wise and cross-attn keys come from the encoder,
        # so rows < 10 see no difference.
        np.testing.assert_allclose(base[:10], pert[:10], rtol=1e-4, atol=1e-4)


class TestFusedConfigs:
    @pytest.mark.parametrize("cfg", FUSED_CONFIGS, ids=lambda c: c.name)
    def test_config_divisibility(self, cfg):
        assert cfg.d_model % cfg.heads == 0
        assert cfg.dk * cfg.heads == cfg.d_model
        assert cfg.hidden == 4 * cfg.d_model

    @pytest.mark.parametrize("cfg", FUSED_CONFIGS, ids=lambda c: c.name)
    def test_fused_fn_shape(self, cfg):
        from compile.aot import _fused_fn, fused_input_shapes
        shapes = fused_input_shapes(cfg)
        args = [jnp.zeros(s, jnp.float32) for s in shapes]
        # zero weights/inputs: LN of zeros is zeros (gamma=0 here) — just
        # verify the traced output shape (bare array since §Perf iter 2's
        # return_tuple=False switch).
        out = jax.eval_shape(_fused_fn(cfg), *args)
        assert out.shape == (cfg.sl, cfg.d_model)
