"""AOT gate: artifacts lower to parseable HLO text and the manifest is
complete and consistent.

Execution of the emitted HLO is validated on the consumer side — the rust
runtime integration tests (rust/tests/integration_runtime.rs) load, compile
and run every artifact against rust-side oracles, which is the path that
actually matters (xla_extension 0.5.1 via the `xla` crate).  Here we verify
the producer half: text-format interchange, manifest completeness, and that
the text parses back into an HloModule.
"""

import json
import os

import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.configs import FUSED_CONFIGS, tile_primitive_specs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def _read(name: str) -> str:
    with open(os.path.join(ART, name)) as f:
        return f.read()


class TestManifest:
    def test_manifest_is_complete(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            m = json.load(f)
        for spec in tile_primitive_specs():
            assert spec.name in m["artifacts"], spec.name
            entry = m["artifacts"][spec.name]
            assert os.path.exists(os.path.join(ART, entry["file"]))
            assert entry["inputs"] == [list(s) for s in spec.inputs]
            assert entry["outputs"] == [list(s) for s in spec.outputs]
        assert m["sl_max"] == 128 and m["ts_mha"] == 64 and m["ts_ffn"] == 128
        assert m["dk"] == 64 and m["dmodel_max"] == 768 and m["hidden_max"] == 3072

    def test_fused_entries(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            m = json.load(f)
        assert set(m["fused"]) >= {c.name for c in FUSED_CONFIGS}
        for name, entry in m["fused"].items():
            assert os.path.exists(os.path.join(ART, entry["file"])), name
            assert len(entry["inputs"]) == 18  # x, mask, 16 LayerParams fields
            cfg = entry["config"]
            assert entry["inputs"][0] == [cfg["sl"], cfg["d_model"]]
            assert entry["outputs"] == [[cfg["sl"], cfg["d_model"]]]

    def test_digest_matches_sources(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            m = json.load(f)
        assert m["digest"] == aot.source_digest(), (
            "artifacts are stale — run `make artifacts`")


class TestHloText:
    @pytest.mark.parametrize("spec", tile_primitive_specs(), ids=lambda s: s.name)
    def test_artifact_is_hlo_text_with_declared_shapes(self, spec):
        text = _read(f"{spec.name}.hlo.txt")
        assert text.startswith("HloModule"), "must be HLO text, not a proto"
        assert "ENTRY" in text
        for shape in spec.inputs:
            dims = ",".join(str(d) for d in shape)
            assert f"f32[{dims}]" in text, (spec.name, shape)

    @pytest.mark.parametrize("spec", tile_primitive_specs(), ids=lambda s: s.name)
    def test_artifact_parses_back(self, spec):
        """HLO text must round-trip through the XLA text parser — the same
        parser class the rust side's HloModuleProto::from_text_file uses."""
        mod = xc._xla.hlo_module_from_text(_read(f"{spec.name}.hlo.txt"))
        assert mod.as_serialized_hlo_module_proto()  # parseable & serializable

    @pytest.mark.parametrize("cfg", FUSED_CONFIGS, ids=lambda c: c.name)
    def test_fused_parses_back(self, cfg):
        mod = xc._xla.hlo_module_from_text(_read(f"fused_{cfg.name}.hlo.txt"))
        assert mod.as_serialized_hlo_module_proto()

    def test_lowering_is_deterministic(self):
        spec = [s for s in tile_primitive_specs() if s.name == "softmax"][0]
        assert aot.lower_primitive(spec) == aot.lower_primitive(spec)

    def test_no_serialized_protos_emitted(self):
        """Guard against regressing to .serialize() (xla_extension 0.5.1
        rejects jax>=0.5 64-bit-id protos — DESIGN.md)."""
        for f in os.listdir(ART):
            if f.endswith(".hlo.txt"):
                with open(os.path.join(ART, f), "rb") as fh:
                    assert fh.read(9) == b"HloModule", f

    def test_mask_and_scale_are_runtime_inputs(self):
        """Runtime adaptivity contract: sequence length (mask) and scale
        enter attention as INPUTS, so changing the `Sequence` register never
        re-lowers anything."""
        text = _read("attn_fused.hlo.txt")
        assert "f32[128,128]" in text  # the mask parameter
        assert "f32[1]" in text        # the scale parameter
