"""Hypothesis sweeps over the Pallas kernels' shapes/values vs ref.py.

These are the L1 property gate: any (shape, value) drawn from the fabric's
legal envelope must match the oracle.  Shapes are constrained to the
divisibility the fabric guarantees (multiples of the block sizes), exactly
as the paper constrains dims to tile-size multiples.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SET = settings(max_examples=20, deadline=None)


def arr(seed: int, shape, lo=-4.0, hi=4.0):
    k = jax.random.PRNGKey(seed)
    return jax.random.uniform(k, shape, jnp.float32, lo, hi)


dims = st.sampled_from([32, 64, 128])
kdims = st.sampled_from([64, 128, 256, 512])
seeds = st.integers(0, 2**31 - 1)


@SET
@given(m=dims, k=kdims, n=dims, seed=seeds)
def test_matmul_acc_matches_ref(m, k, n, seed):
    x, w, acc = arr(seed, (m, k)), arr(seed + 1, (k, n)), arr(seed + 2, (m, n))
    np.testing.assert_allclose(
        kernels.matmul_acc(x, w, acc), ref.matmul_acc(x, w, acc), rtol=1e-4, atol=1e-3)


@SET
@given(m=dims, k=kdims, n=dims, seed=seeds,
       bm=st.sampled_from([16, 32, 64]), bn=st.sampled_from([16, 32, 64]),
       bk=st.sampled_from([32, 64, 128]))
def test_matmul_acc_block_shape_invariance(m, k, n, seed, bm, bn, bk):
    """Result must not depend on the VMEM blocking (pure schedule change)."""
    x, w, acc = arr(seed, (m, k)), arr(seed + 1, (k, n)), arr(seed + 2, (m, n))
    a = kernels.matmul_acc(x, w, acc)
    b = kernels.matmul_acc(x, w, acc, bm=bm, bn=bn, bk=bk)
    # different K-blockings sum in different orders; tolerance covers the
    # worst f32 reassociation error at k=512
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


@SET
@given(sl=st.sampled_from([32, 64, 128]), dk=st.sampled_from([32, 64]),
       valid=st.integers(1, 128), seed=seeds, causal=st.booleans())
def test_attention_padding_independence(sl, dk, valid, seed, causal):
    """Outputs on the valid prefix never depend on padded tail values."""
    valid = min(valid, sl)
    q, k, v = arr(seed, (sl, dk)), arr(seed + 1, (sl, dk)), arr(seed + 2, (sl, dk))
    mask = kernels.padding_mask(sl, valid, causal=causal)
    scale = jnp.array([1.0 / np.sqrt(dk)], jnp.float32)
    base = kernels.attention_head(q, k, v, mask, scale)
    # Scribble on the padded tail; the valid prefix must be unchanged.
    if valid < sl:
        q2 = q.at[valid:].set(99.0)
        k2 = k.at[valid:].set(-99.0)
        v2 = v.at[valid:].set(7.0)
        pert = kernels.attention_head(q2, k2, v2, mask, scale)
        np.testing.assert_allclose(base[:valid], pert[:valid], rtol=1e-4, atol=1e-4)
    oracle = ref.attention_head(q[:valid], k[:valid], v[:valid],
                                kernels.padding_mask(valid, valid, causal=causal),
                                1.0 / np.sqrt(dk))
    np.testing.assert_allclose(base[:valid], oracle, rtol=1e-3, atol=1e-3)


@SET
@given(sl=dims, seed=seeds, scale=st.floats(0.01, 2.0))
def test_softmax_rows_properties(sl, seed, scale):
    s = arr(seed, (sl, sl), -6.0, 6.0) * scale
    p = kernels.softmax_rows(s)
    np.testing.assert_allclose(p, ref.softmax_rows(s), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p).sum(-1), np.ones(sl), rtol=1e-4)
    assert np.asarray(p).min() >= 0.0


@SET
@given(d=st.sampled_from([128, 256, 512, 768]), valid_frac=st.floats(0.25, 1.0),
       seed=seeds)
def test_residual_ln_matches_truncated_exact(d, valid_frac, seed):
    valid = max(8, int(d * valid_frac))
    x, r = arr(seed, (32, d)), arr(seed + 1, (32, d))
    g, b = arr(seed + 2, (d,), 0.5, 1.5), arr(seed + 3, (d,), -0.5, 0.5)
    dm = (jnp.arange(d) < valid).astype(jnp.float32)
    got = kernels.residual_ln(x * dm, r * dm, g, b, dm,
                              jnp.array([float(valid)], jnp.float32))
    np.testing.assert_allclose(
        got, ref.residual_ln(x * dm, r * dm, g, b, dm, float(valid)),
        rtol=1e-3, atol=1e-3)
    z = (x + r)[:, :valid]
    mu, sd = z.mean(-1, keepdims=True), z.std(-1, keepdims=True)
    exact = g[None, :valid] * (z - mu) / jnp.sqrt(sd**2 + 1e-5) + b[None, :valid]
    np.testing.assert_allclose(got[:, :valid], exact, rtol=1e-2, atol=1e-2)


@SET
@given(seed=seeds, scale=st.floats(1e-3, 0.5))
def test_quantize_lattice_and_bound(seed, scale):
    x = arr(seed, (32, 64), -10.0, 10.0)
    q = np.asarray(kernels.quantize_dequantize(x, jnp.array([scale], jnp.float32)))
    ints = q / scale
    np.testing.assert_allclose(ints, np.round(ints), atol=1e-4)
    assert np.abs(ints).max() <= 127 + 1e-4
    inside = np.abs(np.asarray(x)) <= 127 * scale
    if inside.any():
        assert np.abs(q - np.asarray(x))[inside].max() <= scale / 2 + 1e-5


@SET
@given(n=st.sampled_from([64, 128, 512, 768, 3072]), seed=seeds, relu=st.booleans())
def test_bias_add_matches_ref(n, seed, relu):
    x, b = arr(seed, (64, n)), arr(seed + 1, (n,))
    got = kernels.bias_add(x, b, relu=relu)
    want = ref.bias_relu(x, b) if relu else ref.bias_add(x, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
