//! Quickstart: run one transformer encoder inference through the full
//! three-layer stack — rust coordinator → PJRT runtime → AOT-lowered
//! Pallas/JAX artifacts — and check it against the dense CPU oracle.
//!
//!     make artifacts && cargo run --release --example quickstart

use adaptor::coordinator::TileEngine;
use adaptor::model::{presets, reference, weights};
use adaptor::runtime::default_artifact_dir;

fn main() -> anyhow::Result<()> {
    // 1. Bring up the fabric: load the AOT artifact set ("bitstream").
    let mut engine = TileEngine::new(default_artifact_dir())?;
    println!("fabric up: {} tile primitives, SL_MAX={}, d_max={}",
        engine.executor().manifest().artifacts.len(),
        engine.synth_maxima().seq_len,
        engine.synth_maxima().d_model);

    // 2. Pick a topology and program the runtime registers (Algorithm 18).
    let cfg = presets::small_encoder(64, 4); // SL=64, d=256, h=4, 4 layers
    engine.program(&cfg)?;
    println!("registers programmed: {cfg}");

    // 3. Load weights (synthetic, deterministic) and pre-tile them into
    //    the fabric's weight-buffer panels.
    let stack = weights::init_stack(42, cfg.d_model, cfg.heads, cfg.enc_layers);
    let prepared = engine.prepare(&cfg, &stack)?;

    // 4. Run an inference.
    let x = weights::init_input(7, cfg.seq_len, cfg.d_model);
    let t0 = std::time::Instant::now();
    let y = engine.run_encoder(&prepared, &x)?;
    let dt = t0.elapsed();

    // 5. Check against the dense f32 oracle.
    let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
    let want = reference::encoder_stack(&x, &stack, &mask);
    let diff = y.max_abs_diff(&want);

    let stats = engine.executor().stats();
    println!("inference : {:.1} ms wall ({} tile dispatches, {} compiles)",
        dt.as_secs_f64() * 1e3, stats.dispatches, stats.compiles);
    println!("numerics  : max |engine - oracle| = {diff:.2e}");
    assert!(diff < 3e-3, "numerics drifted");
    println!("OK — output row 0, first 6 dims: {:?}",
        &y.data[..6].iter().map(|v| (v * 1e3).round() / 1e3).collect::<Vec<_>>());
    Ok(())
}
