//! Design-space exploration walkthrough (the paper's §3.10 methodology):
//! sweep tile sizes and head counts on the analytical models, pick the
//! optimum, compare against a per-model specialized synthesis, and show
//! the deployment-cost ablation.
//!
//!     cargo run --release --example design_space

use adaptor::accel::platform;
use adaptor::accel::tiling::TileConfig;
use adaptor::analysis::sweep;
use adaptor::baselines::nonadaptive;
use adaptor::model::quant::BitWidth;
use adaptor::model::{presets, TnnConfig};

fn main() {
    let p = platform::u55c();
    let bw = BitWidth::Fixed16;
    let cfg = TnnConfig::encoder(64, 768, 8, 12);

    // --- Fig 5 style tile sweep --------------------------------------
    println!("tile sweep on {} ({}):", p.name, cfg);
    let pts = sweep::tile_sweep(&cfg, &p, bw);
    println!("{:>10} {:>10} {:>10} {:>12} {:>10}", "tiles_mha", "tiles_ffn", "fmax MHz", "latency ms", "GOPS");
    for pt in &pts {
        println!("{:>10} {:>10} {:>10.1} {:>12.2} {:>10.1}{}",
            pt.tiles_mha, pt.tiles_ffn, pt.freq_mhz, pt.latency_ms, pt.gops,
            if pt.fits { "" } else { "   (no fit)" });
    }
    let best = sweep::best_by_latency(&pts).expect("at least one fitting point");
    println!("\n-> optimum: {} MHA tiles x {} FFN tiles (TS {}x{}) at {:.0} MHz — paper picked 12 x 6\n",
        best.tiles_mha, best.tiles_ffn, best.ts_mha, best.ts_ffn, best.freq_mhz);

    // --- Fig 8 style heads sweep --------------------------------------
    println!("head-count sweep (fixed fabric TS 64/128):");
    for pt in sweep::heads_sweep(&cfg, &p, bw) {
        println!("  h={:<3} fmax={:>6.1} MHz  dsp={:<5} latency(norm)={:.3}",
            pt.heads, pt.freq_mhz, pt.dsp, pt.latency_ms);
    }

    // --- specialization vs adaptivity ----------------------------------
    println!("\nper-model specialization (the non-adaptive baseline):");
    for preset in ["shallow", "custom-encoder-4l", "small"] {
        let m = presets::by_name(preset).unwrap();
        if let Some(s) = nonadaptive::specialize(&m, &p, bw) {
            println!("  {:<18} best tiles TS {}x{} -> {:.3} ms @ {:.0} MHz",
                preset, s.tiles.ts_mha, s.tiles.ts_ffn, s.latency_ms, s.freq_mhz);
        }
    }
    let models = vec![
        presets::bert_base(64),
        presets::shallow_transformer(),
        presets::custom_encoder_4l(),
        presets::small_encoder(64, 4),
    ];
    let c = nonadaptive::deployment_cost(&models, &p, &TileConfig::paper_optimum(), bw);
    println!("\ndeployment over {} models:", c.models);
    println!("  ADAPTOR:       {:>6.0} h synthesis, {:>9.1} ms total inference",
        c.adaptor_synthesis_hours, c.adaptor_inference_ms);
    println!("  per-model:     {:>6.0} h synthesis, {:>9.1} ms total inference",
        c.nonadaptive_synthesis_hours, c.nonadaptive_inference_ms);
    println!("  => adaptivity trades milliseconds of inference for {:.0} hours of synthesis",
        c.nonadaptive_synthesis_hours - c.adaptor_synthesis_hours);
}
