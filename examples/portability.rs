//! Portability (paper Fig 11): the same custom TNN encoder (d_model = 200,
//! 3 heads, 2 layers, SL = 64) deployed on three FPGA platforms by
//! adjusting only the synthesis-time tile sizes — Alveo U55C gets the
//! biggest tiles and the lowest latency; ZCU102 and VC707 shrink the tiles
//! to fit, trading latency.
//!
//!     cargo run --release --example portability

use adaptor::accel::{frequency, latency, power, resources, tiling::TileConfig};
use adaptor::accel::platform;
use adaptor::model::quant::BitWidth;
use adaptor::model::presets;

fn main() {
    let cfg = presets::custom_encoder();
    println!("workload: {cfg} (paper Fig 11)\n");
    println!("{:<12} {:>7} {:>7} {:>9} {:>9} {:>9} {:>10} {:>11} {:>8}",
        "platform", "TS_MHA", "TS_FFN", "DSP%", "LUT%", "BRAM%", "fmax MHz", "latency ms", "power W");

    // the paper's per-platform tile choices (§6, Fig 11 discussion)
    let builds = [
        (platform::u55c(), 200usize, 200usize),
        (platform::zcu102(), 25, 50),
        (platform::vc707(), 50, 50),
    ];
    let mut results = Vec::new();
    for (p, ts_mha, ts_ffn) in builds {
        let tiles = TileConfig::for_fabric(ts_mha, ts_ffn, cfg.d_model);
        let r = resources::estimate(&cfg, &tiles, BitWidth::Fixed16, &p);
        let fit = r.check_fit(&p);
        let f = frequency::fmax_mhz(&p, &r);
        let lat = latency::model_latency(&cfg, &tiles).ms_at(f);
        let watts = power::total_power_w(&p, &r, f);
        println!("{:<12} {:>7} {:>7} {:>8.1}% {:>8.1}% {:>8.1}% {:>10.1} {:>11.3} {:>8.1}{}",
            p.name, ts_mha, ts_ffn,
            100.0 * r.dsp_util, 100.0 * r.lut_util, 100.0 * r.bram_util,
            f, lat, watts,
            if fit.is_ok() { "" } else { "  (DOES NOT FIT)" });
        results.push((p.name.clone(), lat));
    }

    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\nfastest -> slowest: {}",
        results.iter().map(|(n, l)| format!("{n} ({l:.2} ms)")).collect::<Vec<_>>().join("  >  "));
    println!("paper's finding reproduced: abundant U55C resources allow maximal tiles
and lowest latency; embedded boards fit the same model with reduced tiles
at near-full utilization and higher latency.");
}
