//! Runtime adaptivity — the paper's headline feature, demonstrated.
//!
//! Four different transformer topologies (different sequence lengths,
//! widths, head counts, depths) execute back-to-back on ONE fabric.  The
//! only thing that changes between them is the configuration register
//! file (paper §3.12); the artifact set is never re-lowered or recompiled
//! — watch the `compiles` counter stay flat, which on the FPGA is "no
//! re-synthesis" (a ~36 hour saving per topology, §3.10).
//!
//!     cargo run --release --example runtime_adaptive

use adaptor::coordinator::TileEngine;
use adaptor::model::{reference, weights, TnnConfig};
use adaptor::runtime::default_artifact_dir;

fn main() -> anyhow::Result<()> {
    let mut engine = TileEngine::new(default_artifact_dir())?;

    let zoo: Vec<(&str, TnnConfig)> = vec![
        ("tiny     ", TnnConfig::encoder(16, 128, 2, 1)),
        ("small    ", TnnConfig::encoder(64, 256, 4, 2)),
        ("mid      ", TnnConfig::encoder(32, 512, 8, 1)),
        ("wide-long", TnnConfig::encoder(128, 640, 10, 1)),
    ];

    println!("{:<10} {:>22} {:>10} {:>12} {:>10} {:>9}",
        "model", "topology", "latency", "dispatches", "compiles", "max err");
    let mut compiles_after_first = None;
    for (i, (name, cfg)) in zoo.iter().enumerate() {
        // the ONLY per-model hardware action: write 7 registers
        engine.program(cfg)?;
        let stack = weights::init_stack(i as u64, cfg.d_model, cfg.heads, cfg.enc_layers);
        let prepared = engine.prepare(cfg, &stack)?;
        let x = weights::init_input(i as u64 + 50, cfg.seq_len, cfg.d_model);

        let d0 = engine.executor().stats().dispatches;
        let t0 = std::time::Instant::now();
        let y = engine.run_encoder(&prepared, &x)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;

        let mask = reference::attention_mask(cfg.seq_len, cfg.seq_len, false);
        let want = reference::encoder_stack(&x, &stack, &mask);
        let stats = engine.executor().stats();
        println!("{:<10} {:>22} {:>8.1}ms {:>12} {:>10} {:>9.1e}",
            name,
            format!("sl={} d={} h={} N={}", cfg.seq_len, cfg.d_model, cfg.heads, cfg.enc_layers),
            ms,
            stats.dispatches - d0,
            stats.compiles,
            y.max_abs_diff(&want));

        match compiles_after_first {
            None => compiles_after_first = Some(stats.compiles),
            Some(n) => assert_eq!(stats.compiles, n, "a topology change re-synthesized!"),
        }
    }
    println!("\nregister write log: {} writes across {} topologies, {} artifact compiles total",
        engine.registers.write_log().len(),
        zoo.len(),
        engine.executor().stats().compiles);
    println!("=> every topology after the first cost ZERO new compilation — the
   FPGA equivalent saves ~36 h of synthesis per model (paper §3.10).");
    Ok(())
}
