//! END-TO-END DRIVER — the full system on a real small workload.
//!
//! Proves all layers compose: Pallas kernels (L1) lowered by JAX (L2) into
//! HLO artifacts, loaded and executed by the PJRT runtime under the rust
//! coordinator (L3) — router → dynamic batcher → single-fabric engine
//! thread — serving concurrent clients across TWO different transformer
//! topologies with runtime register reprogramming and no recompilation.
//! Alongside the served numerics, the FPGA-substrate models estimate what
//! the same workload costs on the paper's U55C build.
//!
//! Results are printed and appended to reports/e2e_serving.txt; the run is
//! recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use std::sync::Arc;
use std::time::{Duration, Instant};

use adaptor::accel::{frequency, latency, resources, tiling::TileConfig};
use adaptor::accel::platform;
use adaptor::coordinator::batcher::BatchPolicy;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{AttentionMode, Request, Server, ServerConfig};
use adaptor::model::quant::BitWidth;
use adaptor::model::{presets, reference, weights, TnnConfig};

const CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 8;

fn main() -> anyhow::Result<()> {
    // --- the deployment: two models share one fabric -----------------
    let small = ModelSpec::new("small-encoder", presets::small_encoder(64, 4), 42);
    let tiny = ModelSpec::new("tiny-encoder", TnnConfig::encoder(32, 128, 2, 2), 43);
    println!("deploying {} ({} params) and {} ({} params) on one fabric",
        small.name, small.cfg.total_params(), tiny.name, tiny.cfg.total_params());

    let mut scfg = ServerConfig::new(vec![small.clone(), tiny.clone()]);
    scfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) };
    scfg.attention = AttentionMode::Fused;
    let t_up = Instant::now();
    let server = Arc::new(Server::start(scfg)?);
    println!("fabric warm in {:.1} ms (artifacts compiled once)\n", t_up.elapsed().as_secs_f64() * 1e3);

    // --- concurrent clients ------------------------------------------
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let s = server.clone();
        let (small, tiny) = (small.clone(), tiny.clone());
        handles.push(std::thread::spawn(move || {
            let mut checked = 0usize;
            for i in 0..REQS_PER_CLIENT {
                let spec = if (c + i) % 3 == 0 { &tiny } else { &small };
                let x = weights::init_input((c * 100 + i) as u64, spec.cfg.seq_len, spec.cfg.d_model);
                let resp = s
                    .infer(Request { model: spec.name.clone(), input: x.clone() })
                    .expect("inference failed");
                // verify every response against the dense oracle
                let mask = reference::attention_mask(spec.cfg.seq_len, spec.cfg.seq_len, false);
                let want = reference::encoder_stack(&x, &spec.weights(), &mask);
                let diff = resp.output.max_abs_diff(&want);
                assert!(diff < 3e-3, "client {c} req {i}: diff {diff}");
                checked += 1;
            }
            checked
        }));
    }
    let verified: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();

    let server = Arc::try_unwrap(server).ok().expect("clients done");
    let metrics = server.shutdown();

    // --- serving report ------------------------------------------------
    let mut out = String::new();
    out.push_str("=== e2e serving run (rust coordinator + PJRT artifacts) ===\n");
    out.push_str(&format!(
        "clients: {CLIENTS} x {REQS_PER_CLIENT} requests over 2 models; all {verified} outputs oracle-verified\n"
    ));
    out.push_str(&format!("wall time: {:.2} s  ({:.2} req/s sustained)\n", wall, verified as f64 / wall));
    out.push_str(&metrics.report());

    // --- what the paper's U55C build would do for the same traffic ----
    let tiles = TileConfig::paper_optimum();
    let p = platform::u55c();
    out.push_str("\n=== FPGA-substrate estimate for the same workload (U55C, TS 64/128) ===\n");
    for spec in [&small, &tiny] {
        let r = resources::estimate(&spec.cfg, &tiles, BitWidth::Fixed16, &p);
        let f = frequency::fmax_mhz(&p, &r);
        let lat = latency::model_latency(&spec.cfg, &tiles);
        out.push_str(&format!(
            "{:<14} {:>8.3} ms/inference @ {:.0} MHz ({:.1} GOPS)\n",
            spec.name,
            lat.ms_at(f),
            f,
            lat.gops_at(&spec.cfg, f)
        ));
    }

    println!("{out}");
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/e2e_serving.txt", &out)?;
    println!("written to reports/e2e_serving.txt");
    Ok(())
}
