//! END-TO-END DRIVER — the full system on a real small workload.
//!
//! Proves all layers compose: Pallas kernels (L1) lowered by JAX (L2) into
//! HLO artifacts, loaded and executed by the PJRT runtime under the rust
//! coordinator (L3) — router → dynamic batcher → fabric **pool** — serving
//! concurrent clients across TWO different transformer topologies with
//! runtime register reprogramming and no recompilation.
//!
//! The run is a saturation demo: the same mixed-model workload is driven
//! through a single fabric (`--pool 1`, the paper's host software) and
//! then through the pool (`--pool N`, default 4), reporting the
//! throughput gain and the affinity scheduler's reprograms-per-request.
//! Alongside the served numerics, the FPGA-substrate models estimate what
//! the same workload costs on the paper's U55C build.
//!
//! Results are printed and appended to reports/e2e_serving.txt.
//!
//!     make artifacts && cargo run --release --example e2e_serving -- [--pool N] [--clients N]

use std::sync::Arc;
use std::time::{Duration, Instant};

use adaptor::accel::platform;
use adaptor::accel::{frequency, latency, resources, tiling::TileConfig};
use adaptor::coordinator::batcher::BatchPolicy;
use adaptor::coordinator::metrics::Metrics;
use adaptor::coordinator::router::ModelSpec;
use adaptor::coordinator::{AttentionMode, Server, ServerConfig};
use adaptor::model::quant::BitWidth;
use adaptor::model::{presets, reference, weights, TnnConfig};
use adaptor::serve::{QoS, Submission};

const REQS_PER_CLIENT: usize = 8;

fn flag_value(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Drive `clients` concurrent clients over the two-model deployment with
/// `pool_size` fabrics; every output is verified against the dense oracle.
fn run_workload(
    small: &ModelSpec,
    tiny: &ModelSpec,
    pool_size: usize,
    clients: usize,
) -> anyhow::Result<(usize, f64, Metrics)> {
    let mut scfg = ServerConfig::new(vec![small.clone(), tiny.clone()]);
    scfg.policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(3) };
    scfg.attention = AttentionMode::Fused;
    scfg.pool_size = pool_size;
    let t_up = Instant::now();
    let server = Arc::new(Server::start(scfg)?);
    println!(
        "  {} fabric(s) warm in {:.1} ms (artifacts compiled once per fabric)",
        pool_size,
        t_up.elapsed().as_secs_f64() * 1e3
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        let (small, tiny) = (small.clone(), tiny.clone());
        handles.push(std::thread::spawn(move || {
            let mut checked = 0usize;
            for i in 0..REQS_PER_CLIENT {
                let spec = if (c + i) % 3 == 0 { &tiny } else { &small };
                let x =
                    weights::init_input((c * 100 + i) as u64, spec.cfg.seq_len, spec.cfg.d_model);
                let out = s
                    .submit(
                        Submission::Encode { model: spec.name.clone(), input: x.clone() },
                        QoS::default(),
                    )
                    .expect("submit failed")
                    .wait()
                    .expect("inference failed")
                    .into_encode()
                    .expect("encode job yields an encode output");
                // verify every response against the dense oracle
                let mask = reference::attention_mask(spec.cfg.seq_len, spec.cfg.seq_len, false);
                let want = reference::encoder_stack(&x, &spec.weights(), &mask);
                let diff = out.output.max_abs_diff(&want);
                assert!(diff < 3e-3, "client {c} req {i}: diff {diff}");
                checked += 1;
            }
            checked
        }));
    }
    let verified: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let wall = t0.elapsed().as_secs_f64();

    // Live snapshot while the pool is still up — Serving API v1 makes
    // shutdown() no longer the only metrics exit.
    let live = server.metrics();
    assert_eq!(live.requests(), verified, "live snapshot must already account for every request");

    let server = Arc::try_unwrap(server).ok().expect("clients done");
    let metrics = server.shutdown()?;
    Ok((verified, wall, metrics))
}

fn main() -> anyhow::Result<()> {
    let pool: usize = flag_value("--pool").and_then(|v| v.parse().ok()).unwrap_or(4);
    let clients: usize = flag_value("--clients").and_then(|v| v.parse().ok()).unwrap_or(4);

    // --- the deployment: two models share the pool --------------------
    let small = ModelSpec::new("small-encoder", presets::small_encoder(64, 4), 42);
    let tiny = ModelSpec::new("tiny-encoder", TnnConfig::encoder(32, 128, 2, 2), 43);
    println!(
        "deploying {} ({} params) and {} ({} params)",
        small.name,
        small.cfg.total_params(),
        tiny.name,
        tiny.cfg.total_params()
    );

    // --- saturation demo: single fabric vs the pool --------------------
    println!("\n[1/2] single fabric (the paper's host software):");
    let (v1, wall1, m1) = run_workload(&small, &tiny, 1, clients)?;
    println!("[2/2] fabric pool (pool_size = {pool}):");
    let (vn, walln, mn) = run_workload(&small, &tiny, pool, clients)?;

    let rps1 = v1 as f64 / wall1;
    let rpsn = vn as f64 / walln;
    let mut out = String::new();
    out.push_str("=== e2e serving run (rust coordinator + PJRT artifacts) ===\n");
    out.push_str(&format!(
        "clients: {clients} x {REQS_PER_CLIENT} requests over 2 models; all {v1}+{vn} outputs oracle-verified\n"
    ));
    out.push_str(&format!("single fabric : {wall1:.2} s  ({rps1:.2} req/s sustained)\n"));
    out.push_str(&format!(
        "pool of {pool:<6}: {walln:.2} s  ({rpsn:.2} req/s sustained, {:.2}x)\n",
        rpsn / rps1
    ));
    out.push_str(&format!(
        "reprograms/request: {:.3} (single) vs {:.3} (pool, affinity)\n",
        m1.reprograms_per_request(),
        mn.reprograms_per_request()
    ));
    if rpsn <= rps1 {
        out.push_str("WARNING: pool did not outperform the single fabric on this host\n");
    }
    out.push_str("\n--- single-fabric metrics ---\n");
    out.push_str(&m1.report());
    out.push_str("\n--- pool metrics (per-fabric breakdown) ---\n");
    out.push_str(&mn.report());

    // --- generation workload: a GPT-style decoder through the pool ----
    // (skipped gracefully on artifact sets predating the decode-step
    // artifacts — re-run `make artifacts`.)
    out.push_str("\n=== generation (decoder-only gpt-small through the pool) ===\n");
    let gpt = ModelSpec::new("gpt-small", presets::gpt_small(32, 2), 44);
    let mut gcfg = ServerConfig::new(vec![gpt.clone()]);
    gcfg.pool_size = pool.min(2);
    match Server::start(gcfg) {
        Err(e) => out.push_str(&format!("generation section skipped: {e}\n")),
        Ok(gserver) => {
            let prompt = weights::init_input(71, 6, gpt.cfg.d_model);
            let steps = 8;
            // Streamed generation: collect tokens as decode steps finish.
            let mut handle = gserver.submit(
                Submission::Generate {
                    model: gpt.name.clone(),
                    prompt: prompt.clone(),
                    source: None,
                    steps,
                },
                QoS::default(),
            )?;
            let mut streamed_tokens = Vec::new();
            let mut streamed_rows: Vec<f32> = Vec::new();
            while let Some(t) = handle.next_token() {
                assert_eq!(t.index, streamed_tokens.len(), "tokens stream in order");
                streamed_tokens.push(t.token);
                streamed_rows.extend_from_slice(&t.row);
            }
            let resp = handle.wait()?.into_generate()?;
            // the stream concatenates bit-identically to the transcript
            assert_eq!(streamed_tokens, resp.tokens, "streamed tokens == final transcript");
            assert_eq!(streamed_rows, resp.rows.data, "streamed rows are bit-identical");
            // verify against the dense greedy-decode oracle
            let want = reference::greedy_decode(&prompt, None, &gpt.decoder_weights(), steps);
            assert_eq!(resp.tokens, want.tokens, "served tokens must match the oracle");
            let diff = resp.rows.max_abs_diff(&want.rows);
            assert!(diff < 5e-3, "generated rows vs oracle diff {diff}");
            let mean_step = resp.step_times.iter().map(|d| d.as_secs_f64()).sum::<f64>()
                / resp.step_times.len().max(1) as f64;
            out.push_str(&format!(
                "{} tokens {:?} (streamed + oracle-verified)\nprefill {:.2} ms, {:.2} ms/token over {} cached steps\n",
                resp.tokens.len(),
                resp.tokens,
                resp.prefill.as_secs_f64() * 1e3,
                mean_step * 1e3,
                resp.step_times.len()
            ));
            // Cancellation: stop a long generation after its first token;
            // the pool keeps serving afterwards.
            let mut doomed = gserver.submit(
                Submission::Generate {
                    model: gpt.name.clone(),
                    prompt: prompt.clone(),
                    source: None,
                    steps: 24,
                },
                QoS::default(),
            )?;
            let _first = doomed.next_token().expect("first token streams before the cancel");
            doomed.cancel();
            match doomed.wait() {
                Err(adaptor::serve::ServeError::Cancelled) => {
                    out.push_str("cancelled a 24-step generation after its first token\n")
                }
                Ok(_) => out.push_str("cancellation raced a short generation to completion\n"),
                Err(e) => return Err(e.into()),
            }
            // Continuous batching: overlap several generations and read
            // the live occupancy metrics — the sequence scheduler must
            // hold more than one generation in flight at once.
            let k = 4usize;
            let gsteps = 16usize;
            let mut overlapped: Vec<_> = (0..k)
                .map(|i| {
                    let p = weights::init_input(200 + i as u64, 6, gpt.cfg.d_model);
                    gserver.submit(
                        Submission::Generate {
                            model: gpt.name.clone(),
                            prompt: p,
                            source: None,
                            steps: gsteps,
                        },
                        QoS::default(),
                    )
                })
                .collect::<Result<_, _>>()?;
            let mut overlapped_tokens = 0usize;
            for h in overlapped.iter_mut() {
                let g = h.wait()?.into_generate()?;
                assert_eq!(g.tokens.len(), gsteps, "every overlapped generation completes");
                overlapped_tokens += g.tokens.len();
            }
            let live = gserver.metrics();
            assert!(
                live.live_peak > 1,
                "continuous batching must overlap generations (in-flight peak {})",
                live.live_peak
            );
            assert!(live.decode_rounds > 0, "scheduler rounds must be counted");
            out.push_str(&format!(
                "overlapped {k} x {gsteps}-token generations: {overlapped_tokens} tokens, \
                 in-flight peak {}, {} scheduler rounds, {} admitted\n",
                live.live_peak, live.decode_rounds, live.admitted
            ));
            let gm = gserver.shutdown()?;
            out.push_str(&gm.report());
        }
    }

    // --- what the paper's U55C build would do for the same traffic ----
    let tiles = TileConfig::paper_optimum();
    let p = platform::u55c();
    out.push_str("\n=== FPGA-substrate estimate for the same workload (U55C, TS 64/128) ===\n");
    for spec in [&small, &tiny] {
        let r = resources::estimate(&spec.cfg, &tiles, BitWidth::Fixed16, &p);
        let f = frequency::fmax_mhz(&p, &r);
        let lat = latency::model_latency(&spec.cfg, &tiles);
        out.push_str(&format!(
            "{:<14} {:>8.3} ms/inference @ {:.0} MHz ({:.1} GOPS)\n",
            spec.name,
            lat.ms_at(f),
            f,
            lat.gops_at(&spec.cfg, f)
        ));
    }

    println!("{out}");
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/e2e_serving.txt", &out)?;
    println!("written to reports/e2e_serving.txt");
    Ok(())
}
